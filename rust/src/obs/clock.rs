//! Monotonic process clocks and cross-process offset estimation.
//!
//! Every process (hub and each worker) timestamps trace events on its own
//! monotonic clock, because no shared clock exists across hosts. To merge
//! the per-rank rings into one fleet-wide timeline the hub estimates each
//! worker's clock offset from a request/response handshake it already
//! performs: it records its own clock when it writes START to a rank and
//! when that rank's first post-START frame arrives; the worker timestamps
//! the START receipt and its reply on *its* clock and ships both numbers
//! inside the TRACE chunk.
//!
//! The estimator is the classic interval argument (NTP's four-timestamp
//! bound, one round): with hub send time `t0`, worker receive time `t1`,
//! worker send time `t2`, hub receive time `t3`, and θ defined as
//! hub-clock minus worker-clock,
//!
//! ```text
//!   t1 + θ ≥ t0        (the request cannot arrive before it was sent)
//!   t2 + θ ≤ t3        (the reply cannot arrive before it was sent)
//!   ⇒  t0 − t1 ≤ θ ≤ t3 − t2
//! ```
//!
//! The midpoint of that interval is the estimate and its half-width the
//! uncertainty — exact under symmetric delays, and never worse than the
//! round-trip time even under fully asymmetric ones. Over a Unix socket
//! the interval is microseconds wide; over TCP it is bounded by RTT.

use std::sync::OnceLock;
use std::time::Instant;

/// Nanoseconds since this process first asked for the time.
///
/// The epoch is pinned lazily by the first call, so stamps taken anywhere
/// in one process (hub thread, service runner, CLI) share an origin.
pub fn now_ns() -> u64 {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    EPOCH.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

/// The four timestamps of one hub↔worker handshake round.
///
/// Hub-side stamps (`hub_send_ns`, `hub_recv_ns`) are on the hub clock;
/// worker-side stamps (`worker_recv_ns`, `worker_send_ns`) are on the
/// worker clock. θ = hub − worker.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HandshakeSample {
    /// Hub clock when the request (START) was written to the rank.
    pub hub_send_ns: u64,
    /// Worker clock when the request was read.
    pub worker_recv_ns: u64,
    /// Worker clock when the reply (TRACE chunk) was written.
    pub worker_send_ns: u64,
    /// Hub clock when the reply was read.
    pub hub_recv_ns: u64,
}

/// Offset estimate: `offset_ns` ± `uncertainty_ns`, θ = hub − worker.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ClockOffset {
    pub offset_ns: i64,
    pub uncertainty_ns: u64,
}

impl ClockOffset {
    /// The identity offset (same process, same clock).
    pub const ZERO: ClockOffset = ClockOffset { offset_ns: 0, uncertainty_ns: 0 };
}

/// Estimate θ = hub-clock − worker-clock from handshake rounds.
///
/// Each sample yields an interval `[t0−t1, t3−t2]` containing θ; the
/// true offset lies in every one, so they are intersected. Samples are
/// taken at different wall times on clocks we treat as drift-free over a
/// phase (monotonic clocks on one machine, or NICs microseconds apart),
/// so an empty intersection means measurement noise exceeded the bound —
/// in that case the tightest single sample wins rather than inventing an
/// impossible interval. Returns [`ClockOffset::ZERO`] for no samples.
pub fn estimate_offset(samples: &[HandshakeSample]) -> ClockOffset {
    let mut best: Option<(i64, i64)> = None;
    for s in samples {
        let lo = s.hub_send_ns as i64 - s.worker_recv_ns as i64;
        let hi = s.hub_recv_ns as i64 - s.worker_send_ns as i64;
        if hi < lo {
            // Degenerate sample (e.g. stamps taken out of order); skip.
            continue;
        }
        best = Some(match best {
            None => (lo, hi),
            Some((blo, bhi)) => {
                let ilo = blo.max(lo);
                let ihi = bhi.min(hi);
                if ilo <= ihi {
                    (ilo, ihi) // consistent: intersect
                } else if (hi - lo) < (bhi - blo) {
                    (lo, hi) // inconsistent: keep the tighter interval
                } else {
                    (blo, bhi)
                }
            }
        });
    }
    match best {
        None => ClockOffset::ZERO,
        Some((lo, hi)) => ClockOffset {
            // Midpoint without i64 overflow on pathological bounds.
            offset_ns: lo + (hi - lo) / 2,
            uncertainty_ns: ((hi - lo) / 2) as u64,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a sample for a true offset θ (hub − worker) with the given
    /// one-way delays. Worker stamps are hub stamps minus θ.
    fn sample(theta: i64, t0: u64, d_req: u64, proc_ns: u64, d_rep: u64) -> HandshakeSample {
        let t1_hub = t0 + d_req; // arrival, in hub time
        let t2_hub = t1_hub + proc_ns;
        let t3 = t2_hub + d_rep;
        HandshakeSample {
            hub_send_ns: t0,
            worker_recv_ns: (t1_hub as i64 - theta) as u64,
            worker_send_ns: (t2_hub as i64 - theta) as u64,
            hub_recv_ns: t3,
        }
    }

    #[test]
    fn symmetric_delays_recover_exact_offset() {
        // Worker clock 5 ms ahead of the hub ⇒ θ = −5 ms.
        let theta = -5_000_000;
        let s = sample(theta, 1_000_000, 400, 100, 400);
        let est = estimate_offset(&[s]);
        assert_eq!(est.offset_ns, theta);
        assert_eq!(est.uncertainty_ns, 400);
    }

    #[test]
    fn skewed_clocks_positive_offset() {
        // Worker clock far behind the hub (started later): θ = +3 s.
        let theta = 3_000_000_000;
        let s = sample(theta, 10_000_000_000, 2_000, 500, 2_000);
        let est = estimate_offset(&[s]);
        assert_eq!(est.offset_ns, theta);
        assert_eq!(est.uncertainty_ns, 2_000);
    }

    #[test]
    fn asymmetric_delay_error_bounded_by_uncertainty() {
        // 10 µs out, 1 µs back: the estimate is biased but the truth
        // stays inside [offset − u, offset + u].
        let theta = 7_000;
        let s = sample(theta, 500_000, 10_000, 0, 1_000);
        let est = estimate_offset(&[s]);
        assert!(est.offset_ns - est.uncertainty_ns as i64 <= theta);
        assert!(theta <= est.offset_ns + est.uncertainty_ns as i64);
        assert_eq!(est.uncertainty_ns, (10_000 + 1_000) / 2);
    }

    #[test]
    fn multiple_samples_intersect_to_tighter_bound() {
        let theta = -42_000;
        // A slow round and a fast round: intersection ≈ the fast one.
        let slow = sample(theta, 0, 50_000, 0, 50_000);
        let fast = sample(theta, 1_000_000, 300, 0, 300);
        let est = estimate_offset(&[slow, fast]);
        assert!(est.uncertainty_ns <= 300);
        assert!((est.offset_ns - theta).abs() <= est.uncertainty_ns as i64);
    }

    #[test]
    fn inconsistent_samples_fall_back_to_tightest() {
        // Two rounds that disagree by more than their widths (clock
        // stepped between them): keep the tighter interval.
        let a = sample(10_000, 0, 100, 0, 100);
        let b = sample(90_000, 1_000_000, 5_000, 0, 5_000);
        let est = estimate_offset(&[b, a]);
        assert_eq!(est.offset_ns, 10_000);
        assert_eq!(est.uncertainty_ns, 100);
    }

    #[test]
    fn degenerate_and_empty_inputs() {
        assert_eq!(estimate_offset(&[]), ClockOffset::ZERO);
        // hi < lo (impossible stamps) is skipped, not propagated.
        let bad = HandshakeSample {
            hub_send_ns: 1_000,
            worker_recv_ns: 0,
            worker_send_ns: 10_000,
            hub_recv_ns: 500,
        };
        assert_eq!(estimate_offset(&[bad]), ClockOffset::ZERO);
    }

    #[test]
    fn now_ns_is_monotonic() {
        let a = now_ns();
        let b = now_ns();
        assert!(b >= a);
    }
}
