//! Small self-contained utilities.
//!
//! The offline build environment ships no `rand`, `proptest`, `criterion`,
//! or `libc`, so this module provides the minimal substitutes the rest of
//! the crate needs: a deterministic PRNG ([`rng::Rng`]), a property-testing
//! harness ([`propcheck`]), a benchmark harness ([`bench_harness`]),
//! plain-text table rendering ([`table`]), and Unix signal plumbing for the
//! service daemon and its workers ([`sig`]).

pub mod bench_harness;
pub mod fault;
pub mod propcheck;
pub mod rng;
#[cfg(unix)]
pub mod sig;
pub mod table;

/// Format a duration given in seconds with sensible units.
pub fn fmt_secs(s: f64) -> String {
    if s >= 100.0 {
        format!("{s:.0} s")
    } else if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.3} us", s * 1e6)
    }
}

/// Mean and (population) standard deviation of a sample.
pub fn mean_sd(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
    (mean, var.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_secs_units() {
        assert_eq!(fmt_secs(123.0), "123 s");
        assert_eq!(fmt_secs(1.5), "1.500 s");
        assert_eq!(fmt_secs(0.0015), "1.500 ms");
        assert_eq!(fmt_secs(0.0000015), "1.500 us");
    }

    #[test]
    fn mean_sd_basic() {
        let (m, s) = mean_sd(&[1.0, 2.0, 3.0]);
        assert!((m - 2.0).abs() < 1e-12);
        assert!((s - (2.0f64 / 3.0).sqrt()).abs() < 1e-12);
        assert_eq!(mean_sd(&[]), (0.0, 0.0));
    }
}
