//! Quickstart: generate a small GWAS-like dataset, run the full
//! three-phase LAMP procedure through the [`parlamp::coordinator`] on
//! *both* fabric backends (OS threads and the discrete-event simulator),
//! cross-check them against the serial reference, and print the
//! statistically significant mutation combinations.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Exits non-zero if the backends disagree with the serial reference or if
//! the planted association fails to reach significance — CI runs this as
//! its smoke test.

use parlamp::coordinator::{Backend, Coordinator, ScreenMode};
use parlamp::datagen::{generate_gwas, GeneticModel, GwasSpec};
use parlamp::lamp::lamp_serial;

fn main() {
    // A 200-SNP, 150-individual cohort with one planted 3-SNP association
    // strong enough (90% penetrance) to survive the LAMP correction.
    let spec = GwasSpec {
        n_snps: 200,
        n_individuals: 150,
        n_pos: 40,
        model: GeneticModel::Dominant,
        maf_upper: 0.2,
        ld_copy_prob: 0.25,
        common_frac: 0.2,
        planted: vec![(3, 0.9)],
        seed: 31,
    };
    let (db, planted) = generate_gwas(&spec);
    println!(
        "dataset: {} items × {} transactions, density {:.2}%, {} positives",
        db.n_items(),
        db.n_trans(),
        db.density() * 100.0,
        db.marginals().n_pos
    );
    println!("planted association: {:?}\n", planted[0]);

    let serial = lamp_serial(&db, 0.05);
    println!("serial reference: {}", serial.summary());

    // One coordinator, two fabric backends. The Auto screen uses the
    // XLA/PJRT artifact when present and falls back to native Fisher.
    let coord = Coordinator::new(0.05).with_screen(ScreenMode::Auto);
    let runs = [
        ("threads", coord.run(&db, &Backend::threads(2)).expect("thread-backend run")),
        ("sim", coord.run(&db, &Backend::sim(8)).expect("sim-backend run")),
    ];
    for (label, run) in &runs {
        println!("coordinator[{label}]: {}", run.summary());
        assert_eq!(run.result.lambda_final, serial.lambda_final, "{label}: λ* mismatch");
        assert_eq!(
            run.result.correction_factor, serial.correction_factor,
            "{label}: correction factor mismatch"
        );
        assert_eq!(
            run.result.significant.len(),
            serial.significant.len(),
            "{label}: significant-set mismatch"
        );
    }

    let res = &runs[1].1.result;
    println!("\nsignificant patterns: {} (FWER ≤ {})", res.significant.len(), res.alpha);
    for (i, s) in res.significant.iter().take(10).enumerate() {
        println!(
            "  {:>2}. {:?}  support={} positives={} P={:.3e}",
            i + 1,
            s.items,
            s.support,
            s.pos_support,
            s.p_value
        );
    }
    assert!(
        !res.significant.is_empty(),
        "planted association must yield a non-empty significant set"
    );
    let recovered =
        res.significant.iter().any(|s| planted[0].iter().all(|i| s.items.contains(i)));
    assert!(recovered, "planted association {:?} not recovered", planted[0]);
    println!("\nOK: both fabric backends agree with the serial reference");
}
