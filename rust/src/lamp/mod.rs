//! LAMP — Limitless-Arity Multiple-testing Procedure (paper §3).
//!
//! Three phases:
//! 1. [`phase1`]: the *support-increase* search finds the optimal minimum
//!    support `λ* − 1` in a single closed-itemset traversal, raising the
//!    running threshold `λ` whenever the count of closed sets with support
//!    ≥ λ exceeds `α / f(λ−1)` (Eq. 3.1 + Fig. 2).
//! 2. [`phase2`]: re-mines at the final minimum support to obtain the
//!    Tarone–Bonferroni correction factor `k = CS(λ*−1)`.
//! 3. [`phase3`]: extracts itemsets with Fisher `P(I) ≤ α / k` among the
//!    closed sets of frequency ≥ λ*−1 (optionally through the XLA screen —
//!    see `runtime::screen`).
//!
//! [`lamp2`] is the serial comparator of Table 2: an occurrence-deliver /
//! conditional-database LCM in the style of LCM v5.3, which wins on sparse
//! many-transaction data and loses on the dense GWAS matrices — the
//! crossover the paper reports.

pub mod lamp2;
pub mod phase1;
pub mod phase2;
pub mod phase3;
pub mod result;
mod rule;

pub use phase1::{phase1_serial, Phase1Result};
pub use phase2::{phase2_count, Phase2Result};
pub use phase3::{phase3_extract, SignificantPattern};
pub use result::LampResult;
pub use rule::SupportIncreaseRule;

use crate::db::Database;

/// Run the complete three-phase LAMP procedure serially.
///
/// This is the reference pipeline; the distributed engines replace phase 1
/// and phase 2's traversals but reuse the same rule and extraction code, so
/// results are bit-identical (asserted by the integration tests).
pub fn lamp_serial(db: &Database, alpha: f64) -> LampResult {
    let p1 = phase1_serial(db, alpha);
    let p2 = phase2_count(db, p1.min_sup);
    let sig = phase3_extract(db, p1.min_sup, p2.correction_factor, alpha);
    LampResult {
        alpha,
        lambda_final: p1.lambda_final,
        min_sup: p1.min_sup,
        correction_factor: p2.correction_factor,
        adjusted_level: alpha / p2.correction_factor as f64,
        significant: sig,
        phase1_closed: p1.stats.closed,
        phase2_closed: p2.closed,
    }
}
