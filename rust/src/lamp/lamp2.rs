//! LAMP2 baseline — occurrence-deliver LCM (Table 2 comparator).
//!
//! The paper compares its bitmap+popcount miner against LAMP2 (Minato et
//! al. 2014), which is built on LCM v5.3: *horizontal* transaction lists,
//! conditional tid-lists, and the occurrence-deliver technique. That engine
//! is asymptotically better on sparse many-transaction data (MCF7) and
//! worse on the dense GWAS matrices — the crossover Table 2 shows. This
//! module is an independent implementation of that style, running the same
//! three LAMP phases so results are comparable pattern-for-pattern.

use crate::db::{Database, Item};
use crate::lcm::{SupportHist, Visit};
use crate::stats::FisherTable;

use super::phase3::SignificantPattern;
use super::result::LampResult;
use super::rule::SupportIncreaseRule;

/// Horizontal view of a database: per-transaction sorted item lists.
#[derive(Clone, Debug)]
pub struct HorizontalDb {
    n_items: usize,
    trans: Vec<Vec<Item>>,
    positive: Vec<bool>,
}

impl HorizontalDb {
    pub fn from_database(db: &Database) -> Self {
        let n_items = db.n_items();
        let n_trans = db.n_trans();
        let mut trans = vec![Vec::new(); n_trans];
        for i in 0..n_items as Item {
            for t in db.col(i).iter_ones() {
                trans[t].push(i);
            }
        }
        let positive = (0..n_trans).map(|t| db.pos_mask().get(t)).collect();
        HorizontalDb { n_items, trans, positive }
    }

    pub fn n_trans(&self) -> usize {
        self.trans.len()
    }

    pub fn n_items(&self) -> usize {
        self.n_items
    }
}

/// A node of the occurrence-deliver search: itemset + tid-list.
#[derive(Clone, Debug)]
struct OdNode {
    items: Vec<Item>,
    core: i64,
    tids: Vec<u32>,
}

/// Mine closed itemsets with the occurrence-deliver engine, with the same
/// dynamic-minimum-support visitor contract as `lcm::mine_closed`.
pub fn mine_closed_od<F>(h: &HorizontalDb, initial_min_sup: u32, mut visit: F) -> u64
where
    F: FnMut(&[Item], u32, &[u32], u32) -> (Visit, u32),
{
    let n = h.n_trans();
    let m = h.n_items;
    let mut min_sup = initial_min_sup.max(1);
    let mut visited: u64 = 0;

    // Root: closure of the empty set = items present in every transaction.
    let all_tids: Vec<u32> = (0..n as u32).collect();
    let mut cnt = vec![0u32; m];
    for t in &h.trans {
        for &i in t {
            cnt[i as usize] += 1;
        }
    }
    let root_items: Vec<Item> =
        (0..m as Item).filter(|&i| cnt[i as usize] == n as u32).collect();
    if !root_items.is_empty() && n as u32 >= min_sup {
        visited += 1;
        let (v, ms) = visit(&root_items, n as u32, &all_tids, min_sup);
        min_sup = ms.max(min_sup);
        if matches!(v, Visit::Stop | Visit::PruneChildren) {
            return visited;
        }
    }

    let mut stack = vec![OdNode { items: root_items, core: -1, tids: all_tids }];
    // Reusable delivery buckets.
    let mut bucket: Vec<Vec<u32>> = vec![Vec::new(); m];
    let mut touched: Vec<Item> = Vec::new();
    let mut ccnt = vec![0u32; m];

    while let Some(node) = stack.pop() {
        // Visit at pop (traversal) time, matching the bitmap engine.
        if node.core >= 0 {
            if (node.tids.len() as u32) < min_sup {
                continue;
            }
            visited += 1;
            let (v, ms) =
                visit(&node.items, node.tids.len() as u32, &node.tids, min_sup);
            min_sup = ms.max(min_sup);
            match v {
                Visit::Stop => return visited,
                Visit::PruneChildren => continue,
                Visit::Continue => {}
            }
        }
        // Occurrence deliver: bucket tids by candidate extension item.
        for &tid in &node.tids {
            for &i in &h.trans[tid as usize] {
                if (i as i64) > node.core && node.items.binary_search(&i).is_err() {
                    if bucket[i as usize].is_empty() {
                        touched.push(i);
                    }
                    bucket[i as usize].push(tid);
                }
            }
        }
        touched.sort_unstable();
        let mut children = Vec::new();
        for &i in &touched {
            let tids = std::mem::take(&mut bucket[i as usize]);
            let sup = tids.len() as u32;
            if sup < min_sup {
                continue;
            }
            // Count every item's frequency inside the candidate denotation
            // (one conditional-database pass).
            let mut cand_items: Vec<Item> = Vec::new();
            for &tid in &tids {
                for &j in &h.trans[tid as usize] {
                    ccnt[j as usize] += 1;
                    if ccnt[j as usize] == 1 {
                        cand_items.push(j);
                    }
                }
            }
            // PPC check + closure completion.
            let mut ok = true;
            let mut closure: Vec<Item> = node.items.clone();
            closure.push(i);
            for &j in &cand_items {
                if ccnt[j as usize] == sup && node.items.binary_search(&j).is_err() && j != i {
                    if j < i {
                        ok = false;
                    } else {
                        closure.push(j);
                    }
                }
            }
            for &j in &cand_items {
                ccnt[j as usize] = 0; // reset scratch
            }
            if !ok {
                continue;
            }
            closure.sort_unstable();
            children.push(OdNode { items: closure, core: i as i64, tids });
        }
        for &k in &touched {
            bucket[k as usize].clear();
        }
        touched.clear();
        // Reverse push for DFS order, matching the bitmap engine.
        while let Some(c) = children.pop() {
            stack.push(c);
        }
    }
    visited
}

/// Full three-phase LAMP on the occurrence-deliver engine.
pub fn lamp2_serial(db: &Database, alpha: f64) -> LampResult {
    let h = HorizontalDb::from_database(db);
    let rule = SupportIncreaseRule::new(db.marginals(), alpha);
    let mut hist = SupportHist::new(db.n_trans());
    let mut lambda: u32 = 1;

    // Phase 1: support increase.
    let p1_visited = mine_closed_od(&h, 1, |_items, sup, _tids, _ms| {
        hist.record(sup);
        lambda = rule.advance(lambda, |l| hist.cs_ge(l));
        (Visit::Continue, lambda)
    });
    let min_sup = lambda.saturating_sub(1).max(1);

    // Phase 2: count at min_sup.
    let mut k: u64 = 0;
    mine_closed_od(&h, min_sup, |_items, _sup, _tids, ms| {
        k += 1;
        (Visit::Continue, ms)
    });
    let k = k.max(1);

    // Phase 3: extract significant patterns.
    let fisher = FisherTable::new(db.marginals());
    let delta = alpha / k as f64;
    let log_delta = delta.ln();
    let mut significant = Vec::new();
    mine_closed_od(&h, min_sup, |items, sup, tids, ms| {
        let n_obs = tids.iter().filter(|&&t| h.positive[t as usize]).count() as u32;
        let log_p = fisher.log_p_value(sup, n_obs);
        if log_p <= log_delta {
            significant.push(SignificantPattern {
                items: items.to_vec(),
                support: sup,
                pos_support: n_obs,
                p_value: log_p.exp(),
            });
        }
        (Visit::Continue, ms)
    });
    significant.sort_by(|a, b| {
        a.p_value.partial_cmp(&b.p_value).unwrap().then_with(|| a.items.cmp(&b.items))
    });

    LampResult {
        alpha,
        lambda_final: lambda,
        min_sup,
        correction_factor: k,
        adjusted_level: delta,
        significant,
        phase1_closed: p1_visited,
        phase2_closed: k,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lamp::lamp_serial;
    use crate::lcm::brute_force_closed;
    use crate::util::propcheck::forall;
    use crate::util::rng::Rng;

    fn random_db(rng: &mut Rng) -> Database {
        let m = 3 + rng.index(6);
        let n = 4 + rng.index(16);
        let trans: Vec<Vec<Item>> = (0..n)
            .map(|_| (0..m as Item).filter(|_| rng.bernoulli(0.45)).collect())
            .collect();
        let labels: Vec<bool> = (0..n).map(|t| t < n.div_ceil(3)).collect();
        Database::from_transactions(m, &trans, &labels)
    }

    #[test]
    fn od_enumeration_matches_brute_force() {
        forall("OD miner == brute force", 40, |rng| {
            let db = random_db(rng);
            let h = HorizontalDb::from_database(&db);
            let min_sup = 1 + rng.below(3) as u32;
            let mut got: Vec<(Vec<Item>, u32)> = Vec::new();
            mine_closed_od(&h, min_sup, |items, sup, _tids, ms| {
                got.push((items.to_vec(), sup));
                (Visit::Continue, ms)
            });
            got.sort();
            let want = brute_force_closed(&db, min_sup);
            if got != want {
                return Err(format!("min_sup={min_sup}\n got {got:?}\nwant {want:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn lamp2_agrees_with_bitmap_lamp() {
        forall("lamp2_serial == lamp_serial", 25, |rng| {
            let db = random_db(rng);
            let a = lamp_serial(&db, 0.05);
            let b = lamp2_serial(&db, 0.05);
            if a.lambda_final != b.lambda_final
                || a.min_sup != b.min_sup
                || a.correction_factor != b.correction_factor
            {
                return Err(format!(
                    "phase1/2 mismatch: bitmap λ*={} k={}, od λ*={} k={}",
                    a.lambda_final, a.correction_factor, b.lambda_final, b.correction_factor
                ));
            }
            if a.significant.len() != b.significant.len() {
                return Err(format!(
                    "phase3 mismatch: {} vs {}",
                    a.significant.len(),
                    b.significant.len()
                ));
            }
            for (x, y) in a.significant.iter().zip(&b.significant) {
                if x.items != y.items || (x.p_value - y.p_value).abs() > 1e-12 {
                    return Err(format!("pattern mismatch {x:?} vs {y:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn tidlists_consistent_with_labels() {
        let mut rng = Rng::new(5);
        let db = random_db(&mut rng);
        let h = HorizontalDb::from_database(&db);
        assert_eq!(h.n_trans(), db.n_trans());
        assert_eq!(h.n_items(), db.n_items());
        mine_closed_od(&h, 1, |items, sup, tids, ms| {
            assert_eq!(sup as usize, tids.len());
            assert_eq!(db.support(items), sup);
            (Visit::Continue, ms)
        });
    }
}
