//! Prometheus text exposition of [`ServiceStats`].
//!
//! `parlamp stats --format prom` renders the same STATS frame the human
//! report uses as the Prometheus text format (version 0.0.4), so a
//! textfile-collector or a thin exec exporter can scrape the daemon
//! without any new wire surface. Counters keep the `_total` suffix
//! convention; the log₂ latency histograms are re-expressed as native
//! cumulative `_bucket{le="…"}` series in seconds (bucket `i` of the
//! STATS frame covers `[2^i, 2^(i+1))` ms, so its upper bound is
//! `2^(i+1)/1000` s). The frame carries no latency sums, so `_sum` is
//! reported as 0 and documented as untracked in HELP — explicit, not
//! silently plausible.

use crate::wire::service::ServiceStats;
use std::fmt::Write as _;

/// Escape a label value per the exposition format.
fn label(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

fn histogram(out: &mut String, name: &str, help: &str, buckets: &[u64]) {
    let _ = writeln!(out, "# HELP {name} {help} (_sum not tracked; reported as 0)");
    let _ = writeln!(out, "# TYPE {name} histogram");
    let mut cum: u64 = 0;
    for (i, &count) in buckets.iter().enumerate() {
        cum += count;
        let le = (1u64 << (i + 1)) as f64 / 1000.0;
        let _ = writeln!(out, "{name}_bucket{{le=\"{le}\"}} {cum}");
    }
    let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {cum}");
    let _ = writeln!(out, "{name}_sum 0");
    let _ = writeln!(out, "{name}_count {cum}");
}

/// Render a STATS snapshot as Prometheus text exposition.
pub fn render(s: &ServiceStats) -> String {
    let mut out = String::with_capacity(2048);

    let _ = writeln!(out, "# HELP parlamp_uptime_seconds Daemon uptime.");
    let _ = writeln!(out, "# TYPE parlamp_uptime_seconds gauge");
    let _ = writeln!(out, "parlamp_uptime_seconds {}", s.uptime_ms as f64 / 1e3);

    let _ = writeln!(out, "# HELP parlamp_jobs_total Jobs by terminal or admission state.");
    let _ = writeln!(out, "# TYPE parlamp_jobs_total counter");
    for (state, v) in [
        ("submitted", s.jobs_submitted),
        ("mined", s.jobs_mined),
        ("failed", s.jobs_failed),
        ("rejected_busy", s.jobs_rejected_busy),
        ("expired", s.jobs_expired),
        ("cancelled", s.jobs_cancelled),
    ] {
        let _ = writeln!(out, "parlamp_jobs_total{{state=\"{state}\"}} {v}");
    }

    let _ = writeln!(out, "# HELP parlamp_cache_hits_total In-memory result-cache hits.");
    let _ = writeln!(out, "# TYPE parlamp_cache_hits_total counter");
    let _ = writeln!(out, "parlamp_cache_hits_total {}", s.cache_hits);
    let _ = writeln!(out, "# HELP parlamp_cache_misses_total In-memory result-cache misses.");
    let _ = writeln!(out, "# TYPE parlamp_cache_misses_total counter");
    let _ = writeln!(out, "parlamp_cache_misses_total {}", s.cache_misses);
    let _ = writeln!(out, "# HELP parlamp_cache_entries Resident result-cache entries.");
    let _ = writeln!(out, "# TYPE parlamp_cache_entries gauge");
    let _ = writeln!(out, "parlamp_cache_entries {}", s.cache_entries);

    let _ = writeln!(out, "# HELP parlamp_store_entries Records indexed in the persistent store.");
    let _ = writeln!(out, "# TYPE parlamp_store_entries gauge");
    let _ = writeln!(out, "parlamp_store_entries {}", s.store_entries);
    let _ = writeln!(out, "# HELP parlamp_store_appends_total Records appended to the store.");
    let _ = writeln!(out, "# TYPE parlamp_store_appends_total counter");
    let _ = writeln!(out, "parlamp_store_appends_total {}", s.store_appends);
    let _ = writeln!(out, "# HELP parlamp_store_hits_total LRU misses answered from disk.");
    let _ = writeln!(out, "# TYPE parlamp_store_hits_total counter");
    let _ = writeln!(out, "parlamp_store_hits_total {}", s.store_hits);

    let _ = writeln!(out, "# HELP parlamp_history_evicted_total Terminal job records evicted.");
    let _ = writeln!(out, "# TYPE parlamp_history_evicted_total counter");
    let _ = writeln!(out, "parlamp_history_evicted_total {}", s.evicted_records);

    let _ = writeln!(out, "# HELP parlamp_fleet_jobs_total Jobs mined, per fleet.");
    let _ = writeln!(out, "# TYPE parlamp_fleet_jobs_total counter");
    for (i, fl) in s.fleets.iter().enumerate() {
        let _ = writeln!(out, "parlamp_fleet_jobs_total{{fleet=\"{i}\"}} {}", fl.jobs_mined);
    }
    let _ = writeln!(out, "# HELP parlamp_fleet_busy_seconds_total Mining wall-clock, per fleet.");
    let _ = writeln!(out, "# TYPE parlamp_fleet_busy_seconds_total counter");
    for (i, fl) in s.fleets.iter().enumerate() {
        let _ = writeln!(
            out,
            "parlamp_fleet_busy_seconds_total{{fleet=\"{i}\"}} {}",
            fl.busy_ms as f64 / 1e3
        );
    }
    let _ = writeln!(out, "# HELP parlamp_fleet_respawns_total Worker ranks respawned in place.");
    let _ = writeln!(out, "# TYPE parlamp_fleet_respawns_total counter");
    for (i, fl) in s.fleets.iter().enumerate() {
        let _ = writeln!(out, "parlamp_fleet_respawns_total{{fleet=\"{i}\"}} {}", fl.respawns);
    }
    let _ = writeln!(out, "# HELP parlamp_fleet_rebuilds_total Whole-fleet rebuilds.");
    let _ = writeln!(out, "# TYPE parlamp_fleet_rebuilds_total counter");
    for (i, fl) in s.fleets.iter().enumerate() {
        let _ = writeln!(out, "parlamp_fleet_rebuilds_total{{fleet=\"{i}\"}} {}", fl.rebuilds);
    }

    let _ = writeln!(out, "# HELP parlamp_client_queued Jobs queued, per client.");
    let _ = writeln!(out, "# TYPE parlamp_client_queued gauge");
    for c in &s.clients {
        let v = c.queued;
        let _ = writeln!(out, "parlamp_client_queued{{client=\"{}\"}} {v}", label(&c.client));
    }
    let _ = writeln!(out, "# HELP parlamp_client_active Jobs running on a fleet, per client.");
    let _ = writeln!(out, "# TYPE parlamp_client_active gauge");
    for c in &s.clients {
        let v = c.active;
        let _ = writeln!(out, "parlamp_client_active{{client=\"{}\"}} {v}", label(&c.client));
    }
    let _ = writeln!(out, "# HELP parlamp_client_submitted_total Submissions, per client.");
    let _ = writeln!(out, "# TYPE parlamp_client_submitted_total counter");
    for c in &s.clients {
        let _ = writeln!(
            out,
            "parlamp_client_submitted_total{{client=\"{}\"}} {}",
            label(&c.client),
            c.submitted
        );
    }

    histogram(
        &mut out,
        "parlamp_queue_wait_seconds",
        "Submit-to-dispatch wait.",
        &s.queue_wait_ms,
    );
    histogram(
        &mut out,
        "parlamp_job_latency_seconds",
        "Submit-to-terminal latency.",
        &s.latency_ms,
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::wire::service::{ClientStats, FleetStats};

    fn sample() -> ServiceStats {
        ServiceStats {
            uptime_ms: 2_500,
            jobs_submitted: 5,
            jobs_mined: 3,
            jobs_failed: 0,
            jobs_rejected_busy: 1,
            jobs_expired: 1,
            jobs_cancelled: 0,
            cache_hits: 2,
            cache_misses: 3,
            cache_entries: 3,
            store_entries: 3,
            store_appends: 3,
            store_hits: 1,
            evicted_records: 0,
            fleets: vec![
                FleetStats { jobs_mined: 2, busy_ms: 1_500, respawns: 1, rebuilds: 0 },
                FleetStats { jobs_mined: 1, busy_ms: 400, respawns: 0, rebuilds: 1 },
            ],
            clients: vec![ClientStats {
                client: "tenant \"a\"".into(),
                queued: 1,
                active: 0,
                submitted: 4,
            }],
            queue_wait_ms: vec![2, 0, 1],
            latency_ms: vec![0, 0, 0],
        }
    }

    #[test]
    fn renders_well_formed_metric_lines() {
        let out = render(&sample());
        assert!(out.contains("# TYPE parlamp_jobs_total counter"), "{out}");
        assert!(out.contains("parlamp_jobs_total{state=\"mined\"} 3"), "{out}");
        assert!(out.contains("parlamp_uptime_seconds 2.5"), "{out}");
        assert!(out.contains("parlamp_fleet_respawns_total{fleet=\"0\"} 1"), "{out}");
        assert!(out.contains("parlamp_fleet_busy_seconds_total{fleet=\"1\"} 0.4"), "{out}");
        // Every non-comment line is `name{labels} value` or `name value`.
        for line in out.lines().filter(|l| !l.starts_with('#')) {
            let (head, value) = line.rsplit_once(' ').expect("line must have a value");
            assert!(!head.is_empty() && value.parse::<f64>().is_ok(), "bad line: {line}");
        }
    }

    #[test]
    fn histogram_buckets_are_cumulative_in_seconds() {
        let out = render(&sample());
        // queue_wait_ms = [2, 0, 1]: bounds 2ms, 4ms, 8ms → 0.002/0.004/0.008 s
        assert!(out.contains("parlamp_queue_wait_seconds_bucket{le=\"0.002\"} 2"), "{out}");
        assert!(out.contains("parlamp_queue_wait_seconds_bucket{le=\"0.004\"} 2"), "{out}");
        assert!(out.contains("parlamp_queue_wait_seconds_bucket{le=\"0.008\"} 3"), "{out}");
        assert!(out.contains("parlamp_queue_wait_seconds_bucket{le=\"+Inf\"} 3"), "{out}");
        assert!(out.contains("parlamp_queue_wait_seconds_count 3"), "{out}");
        assert!(out.contains("parlamp_job_latency_seconds_bucket{le=\"+Inf\"} 0"), "{out}");
    }

    #[test]
    fn label_values_are_escaped() {
        let out = render(&sample());
        assert!(out.contains(r#"client="tenant \"a\"""#), "{out}");
        assert_eq!(label("a\\b\"c\nd"), "a\\\\b\\\"c\\nd");
    }
}
