//! Aggregate result of a LAMP run.

use super::phase3::SignificantPattern;

/// Everything a LAMP run reports (matches the columns of Table 1 plus the
/// phase-3 output of §5.6).
#[derive(Clone, Debug)]
pub struct LampResult {
    pub alpha: f64,
    /// Final λ of the support-increase search.
    pub lambda_final: u32,
    /// Optimal minimum support `λ_final − 1` (the paper's Table 1 λ column
    /// reports this value).
    pub min_sup: u32,
    /// Correction factor `k = CS(min_sup)` (Table 1 "nu. CS").
    pub correction_factor: u64,
    /// Adjusted per-test level `δ = α / k`.
    pub adjusted_level: f64,
    /// Significant patterns, ascending P-value.
    pub significant: Vec<SignificantPattern>,
    /// Closed sets visited during (pruned) phase 1.
    pub phase1_closed: u64,
    /// Closed sets counted in phase 2 (= `correction_factor`).
    pub phase2_closed: u64,
}

impl LampResult {
    /// Largest significant pattern arity (paper §5.6 reports 8 for
    /// HapMap dom 20).
    pub fn max_arity(&self) -> usize {
        self.significant.iter().map(|s| s.items.len()).max().unwrap_or(0)
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "λ*={} min_sup={} k={} δ={:.3e} significant={} max_arity={}",
            self.lambda_final,
            self.min_sup,
            self.correction_factor,
            self.adjusted_level,
            self.significant.len(),
            self.max_arity()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_and_arity() {
        let r = LampResult {
            alpha: 0.05,
            lambda_final: 5,
            min_sup: 4,
            correction_factor: 42,
            adjusted_level: 0.05 / 42.0,
            significant: vec![SignificantPattern {
                items: vec![1, 2, 3],
                support: 7,
                pos_support: 6,
                p_value: 1e-5,
            }],
            phase1_closed: 10,
            phase2_closed: 42,
        };
        assert_eq!(r.max_arity(), 3);
        assert!(r.summary().contains("min_sup=4"));
    }
}
