//! The Fig. 5 `ParallelDFS` worker state machine.
//!
//! One instance per process. All protocol behaviour lives here, written
//! against the abstract [`Mailbox`], so the thread engine and the
//! discrete-event engine execute *the same code* — the DES results are the
//! protocol's real dynamics, only time is virtual.
//!
//! Protocol summary (paper §4.2, §4.5):
//! - **Preprocess**: every process expands the depth-1 children whose core
//!   item `i` satisfies `i mod P = rank`, then the depth-1 histogram is
//!   reduced over the ternary tree and the initial λ broadcast back.
//! - **Main loop**: pop + expand between probes; requests arrive and are
//!   answered with half the stack (GIVE) or a REJECT; when the local stack
//!   empties, try `w` random steals (awaiting each reply), then send
//!   lifeline requests and go idle. Lifeline requests are *recorded* by an
//!   empty victim and served by `Distribute` as soon as it has surplus.
//! - **Termination**: Mattern waves (see [`crate::dtd`]), λ piggybacked.

use std::time::Instant;

use crate::db::Database;
use crate::dtd::{DtdNode, SpanningTree, WaveOutcome};
use crate::fabric::{BasicKind, CommStats, HistDelta, Mailbox, Msg, WireTask};
use crate::glb::Lifelines;
use crate::lamp::SupportIncreaseRule;
use crate::lcm::{expand, expand_filtered, ExpandScratch, SearchNode, SupportHist};
use crate::obs::clock;
use crate::obs::trace::{EventKind, TraceEvent, TraceRing};
use crate::util::rng::Rng;

use super::breakdown::Breakdown;

/// What a parallel run computes.
#[derive(Clone, Copy, Debug)]
pub enum RunMode {
    /// LAMP phase 1: support-increase search from λ = 1 at level `alpha`.
    Phase1 { alpha: f64 },
    /// LAMP phase 2 (or plain closed mining): count at fixed support.
    Count { min_sup: u32 },
}

impl RunMode {
    /// The LAMP phase number this mode executes, as stamped into trace
    /// `PhaseStart`/`PhaseEnd` events (DESIGN.md §14). Phase 3 — the
    /// screen — never runs on a worker; the coordinator records it on the
    /// hub track.
    pub fn phase_no(&self) -> u8 {
        match self {
            RunMode::Phase1 { .. } => 1,
            RunMode::Count { .. } => 2,
        }
    }
}

/// Static per-worker configuration.
#[derive(Clone, Debug)]
pub struct WorkerConfig {
    pub rank: usize,
    pub p: usize,
    /// Random steal attempts before falling back to lifelines (paper: 1).
    pub w: usize,
    /// Hypercube edge length (paper: 2).
    pub l: usize,
    /// Spanning-tree arity for DTD (paper: ternary = 3).
    pub tree_arity: usize,
    /// `false` = the naive static-partition baseline of §5.4.
    pub steal: bool,
    /// Depth-1 preprocess partition (§4.5). When `false`, rank 0 starts
    /// with the whole tree (ablation).
    pub preprocess: bool,
    pub mode: RunMode,
    /// Work budget between probes, in expansion cost units (§4.6 tunes
    /// this to ≈1 ms).
    pub probe_budget_units: u64,
    /// Interval between DTD waves in (virtual or real) nanoseconds.
    pub dtd_interval_ns: u64,
    /// Nanoseconds charged per expansion cost unit in virtual-time mode;
    /// `None` = real time (thread engine).
    pub ns_per_unit: Option<f64>,
    pub seed: u64,
}

impl WorkerConfig {
    /// Paper-default knobs for a world of `p` processes.
    pub fn paper_defaults(rank: usize, p: usize, mode: RunMode, seed: u64) -> Self {
        WorkerConfig {
            rank,
            p,
            w: 1,
            l: 2,
            tree_arity: 3,
            steal: true,
            preprocess: true,
            mode,
            probe_budget_units: 4_000_000, // ≈1 ms at 0.25 ns/unit (§4.6)
            dtd_interval_ns: 1_000_000,    // 1 ms wave cadence
            ns_per_unit: Some(0.25),
            seed,
        }
    }
}

/// Outcome of one `poll` call, driving the engine's scheduling.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Poll {
    /// Did `cost_ns` of work (or message handling); poll again after that
    /// much (virtual) time.
    Busy { cost_ns: u64 },
    /// Nothing to do; wake on message arrival, or at `wake_at` if set
    /// (root's next DTD wave).
    Idle { wake_at: Option<u64> },
    /// Saw `Finish`; never poll again.
    Finished,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Phase {
    Preprocess,
    AwaitBarrier,
    Main,
    Done,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum StealState {
    /// Have (or may have) local work; no outstanding request.
    HaveWork,
    /// One random REQUEST outstanding (`tries` already used).
    AwaitReply { tries: usize },
    /// Lifeline requests posted; waiting for a GIVE.
    LifelinesOut,
}

/// The per-process worker.
pub struct Worker<'d> {
    db: &'d Database,
    cfg: WorkerConfig,
    lifelines: Lifelines,
    dtd: DtdNode,
    rng: Rng,
    phase: Phase,
    steal_state: StealState,
    stack: Vec<SearchNode>,
    scratch: ExpandScratch,

    /// Current (possibly stale) global λ / fixed minimum support.
    lambda: u32,
    /// Cumulative local histogram (exact; merged by the engine at the end).
    local_hist: SupportHist,
    /// Delta since the last wave visit (drained into WaveUp/PreUp).
    wave_delta: Vec<u64>,
    closed_count: u64,
    work_units: u64,

    /// Lifeline neighbors we have an outstanding request to.
    activated: Vec<bool>,
    /// Lifeline requesters recorded while we were empty (Distribute serves
    /// these as soon as work exists).
    incoming_lifelines: Vec<usize>,

    // Preprocess barrier state.
    pre_local_done: bool,
    pre_pending: usize,
    pre_hist: HistDelta,

    // Root-only: support-increase rule + aggregated histogram + wave timer.
    rule: Option<SupportIncreaseRule>,
    root_hist: SupportHist,
    next_wave_at: u64,
    wave_in_flight: bool,

    // Accounting.
    pub breakdown: Breakdown,
    pub comm: CommStats,
    main_started_at: Option<u64>,
    t0: Instant,

    // Observability (DESIGN.md §14): per-rank event ring, allocated only
    // when the global trace flag is armed — `None` costs one branch per
    // hook site and nothing else.
    trace: Option<TraceRing>,
    /// DES virtual "now" of the current quantum; real-mode hooks stamp
    /// the process-wide monotonic clock instead.
    trace_vnow: u64,
}

impl<'d> Worker<'d> {
    pub fn new(db: &'d Database, cfg: WorkerConfig) -> Self {
        let lifelines = Lifelines::new(cfg.rank, cfg.p, cfg.l);
        let tree = SpanningTree::with_arity(cfg.rank, cfg.p, cfg.tree_arity);
        let pre_pending = tree.children().len();
        let dtd = DtdNode::new(tree);
        let rng = Rng::new(cfg.seed ^ (cfg.rank as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let lambda = match cfg.mode {
            RunMode::Phase1 { .. } => 1,
            RunMode::Count { min_sup } => min_sup.max(1),
        };
        let rule = match cfg.mode {
            RunMode::Phase1 { alpha } if cfg.rank == 0 => {
                Some(SupportIncreaseRule::new(db.marginals(), alpha))
            }
            _ => None,
        };
        let n_ll = lifelines.z();
        let phase = if cfg.preprocess { Phase::Preprocess } else { Phase::Main };
        let mut w = Worker {
            db,
            cfg,
            lifelines,
            dtd,
            rng,
            phase,
            steal_state: StealState::HaveWork,
            stack: Vec::new(),
            scratch: ExpandScratch::default(),
            lambda,
            local_hist: SupportHist::new(db.n_trans()),
            wave_delta: vec![0; db.n_trans() + 1],
            closed_count: 0,
            work_units: 0,
            activated: vec![false; n_ll],
            incoming_lifelines: Vec::new(),
            pre_local_done: false,
            pre_pending,
            pre_hist: Vec::new(),
            rule,
            root_hist: SupportHist::new(db.n_trans()),
            next_wave_at: 0,
            wave_in_flight: false,
            breakdown: Breakdown::default(),
            comm: CommStats::default(),
            main_started_at: None,
            t0: Instant::now(),
            trace: crate::obs::trace::enabled().then(TraceRing::with_default_cap),
            trace_vnow: 0,
        };
        if !w.cfg.preprocess && w.cfg.rank == 0 {
            // Whole tree starts at the root process (§4.5 without the
            // depth-1 distribution).
            w.push_root();
            w.main_started_at = Some(0);
        } else if !w.cfg.preprocess {
            w.main_started_at = Some(0);
        }
        w
    }

    fn push_root(&mut self) {
        let root = SearchNode::root(self.db);
        if !root.items.is_empty() && root.support >= self.lambda {
            self.record_closed(root.support);
        }
        self.stack.push(root);
    }

    // ---- accounting helpers -------------------------------------------

    /// Convert expansion cost units to nanoseconds.
    fn units_to_ns(&self, units: u64) -> u64 {
        match self.cfg.ns_per_unit {
            Some(k) => ((units as f64) * k) as u64,
            None => 0, // real-time mode measures wall clock instead
        }
    }

    fn real_now_ns(&self) -> u64 {
        self.t0.elapsed().as_nanos() as u64
    }

    // ---- observability hooks (DESIGN.md §14) --------------------------

    /// Record `kind` into the event ring, if tracing is armed. Stamps DES
    /// virtual time under the sim cost model (exactly reproducible run to
    /// run) and the process-wide monotonic clock otherwise, so worker
    /// events share the epoch of the fabric's clock-handshake stamps.
    #[inline]
    pub fn trace_event(&mut self, kind: EventKind) {
        if let Some(tr) = &mut self.trace {
            let t = if self.cfg.ns_per_unit.is_some() {
                self.trace_vnow
            } else {
                clock::now_ns()
            };
            tr.push(t, kind);
        }
    }

    /// Drain the event ring for flushing: `(events, dropped)`. `None`
    /// when tracing was off when this worker was built.
    pub fn take_trace(&mut self) -> Option<(Vec<TraceEvent>, u64)> {
        self.trace.as_mut().map(TraceRing::take)
    }

    fn record_closed(&mut self, support: u32) {
        self.local_hist.record(support);
        self.wave_delta[support as usize] += 1;
        self.closed_count += 1;
    }

    fn drain_wave_delta(&mut self) -> HistDelta {
        let mut out = Vec::new();
        for (s, c) in self.wave_delta.iter_mut().enumerate() {
            if *c > 0 {
                out.push((s as u32, *c));
                *c = 0;
            }
        }
        out
    }

    // ---- messaging helpers --------------------------------------------

    fn send_basic(&mut self, mb: &mut dyn Mailbox, dst: usize, kind: BasicKind) {
        let stamp = self.dtd.on_basic_sent();
        let msg = Msg::Basic { stamp, kind };
        self.comm.sent += 1;
        self.comm.bytes_sent += msg.wire_bytes() as u64;
        mb.send(dst, msg);
    }

    fn send_ctrl(&mut self, mb: &mut dyn Mailbox, dst: usize, msg: Msg) {
        self.comm.sent += 1;
        self.comm.bytes_sent += msg.wire_bytes() as u64;
        mb.send(dst, msg);
    }

    /// Is this process idle from the DTD's point of view?
    fn idle_vote(&self) -> bool {
        self.stack.is_empty() && self.phase == Phase::Main
    }

    // ---- the paper's Fig. 5 loop, one scheduling quantum ----------------

    /// Run one quantum: handle pending messages, then either preprocess,
    /// expand nodes up to the probe budget, distribute to lifelines, or
    /// advance the steal protocol.
    pub fn poll(&mut self, mb: &mut dyn Mailbox, now_ns: u64) -> Poll {
        if self.phase == Phase::Done {
            return Poll::Finished;
        }
        if self.trace.is_some() {
            self.trace_vnow = now_ns;
        }
        let real_mode = self.cfg.ns_per_unit.is_none();
        let probe_t0 = if real_mode { self.real_now_ns() } else { 0 };
        let mut cost_ns: u64 = 0;

        // Probe: drain every pending message (MPI_Iprobe loop, Fig. 5).
        let mut handled = 0u64;
        while let Some((src, msg)) = mb.try_recv() {
            self.comm.received += 1;
            handled += 1;
            self.handle(mb, src, msg, now_ns);
            if self.phase == Phase::Done {
                // Finish may arrive mid-drain.
                let probe_ns =
                    if real_mode { self.real_now_ns() - probe_t0 } else { handled * 300 };
                self.breakdown.probe_ns += probe_ns;
                return Poll::Finished;
            }
        }
        let probe_ns = if real_mode { self.real_now_ns() - probe_t0 } else { handled * 300 };
        self.breakdown.probe_ns += probe_ns;
        cost_ns += probe_ns;

        match self.phase {
            Phase::Done => return Poll::Finished,
            Phase::Preprocess => {
                if !self.pre_local_done {
                    cost_ns += self.do_preprocess(mb);
                    return Poll::Busy { cost_ns: cost_ns.max(100) };
                }
                // Internal tree node waiting for children's PreUp reports.
                return Poll::Idle { wake_at: None };
            }
            Phase::AwaitBarrier => {
                return Poll::Idle { wake_at: None };
            }
            Phase::Main => {}
        }
        if self.main_started_at.is_none() {
            self.main_started_at = Some(now_ns);
            // Paper convention (Fig. 7 / §5.2): *everything* before the
            // barrier release — including the waiting — is "preprocess".
            self.breakdown.preprocess_ns = if real_mode { self.real_now_ns() } else { now_ns };
            self.breakdown.probe_ns = 0; // folded into the preprocess span
        }

        // Root: wave cadence (λ gather/broadcast + termination detection).
        if self.cfg.rank == 0 && !self.wave_in_flight && now_ns >= self.next_wave_at {
            self.start_wave(mb, now_ns);
        }

        // Distribute: serve recorded lifelines out of surplus (Fig. 5's
        // Distribute() call).
        if self.cfg.steal {
            cost_ns += self.distribute(mb);
        }

        // Main work: expand until the probe budget is spent.
        if !self.stack.is_empty() {
            self.steal_state = StealState::HaveWork;
            let main_t0 = if real_mode { self.real_now_ns() } else { 0 };
            let mut spent_units = 0u64;
            while spent_units < self.cfg.probe_budget_units {
                let Some(mut node) = self.stack.pop() else { break };
                if node.core >= 0 {
                    if node.support < self.lambda {
                        continue; // λ rose past this subtree
                    }
                    self.record_closed(node.support);
                }
                let st =
                    expand(self.db, &mut node, self.lambda, &mut self.scratch, &mut self.stack);
                // Charge candidate-loop *and* database-reduction work: the
                // DES cost model and the probe budget both run on total
                // expansion units (DESIGN.md §8).
                spent_units += st.units().max(1);
                self.work_units += st.units();
            }
            if spent_units > 0 {
                self.trace_event(EventKind::ExpandBatch { units: spent_units });
            }
            let main_ns = if real_mode {
                self.real_now_ns() - main_t0
            } else {
                self.units_to_ns(spent_units)
            };
            self.breakdown.main_ns += main_ns;
            cost_ns += main_ns;
            return Poll::Busy { cost_ns: cost_ns.max(100) };
        }

        // Stack empty: advance the steal protocol.
        if self.cfg.p > 1 && self.cfg.steal {
            if self.steal_state == StealState::HaveWork {
                self.steal_state = self.begin_steal(mb);
                return Poll::Busy { cost_ns: cost_ns.max(100) };
            }
        }
        // Idle: waiting for GIVE / waves / Finish.
        let wake = if self.cfg.rank == 0 && !self.wave_in_flight {
            Some(self.next_wave_at.max(now_ns + 1))
        } else {
            None
        };
        Poll::Idle { wake_at: wake }
    }

    /// Depth-1 static partition (§4.5): expand the root for items with
    /// `i mod P == rank`, then enter the barrier.
    fn do_preprocess(&mut self, mb: &mut dyn Mailbox) -> u64 {
        debug_assert!(!self.pre_local_done);
        let real_mode = self.cfg.ns_per_unit.is_none();
        let t0 = if real_mode { self.real_now_ns() } else { 0 };
        let mut root = SearchNode::root(self.db);
        if self.cfg.rank == 0 && !root.items.is_empty() && root.support >= self.lambda {
            self.record_closed(root.support);
        }
        let (rank, p) = (self.cfg.rank as u32, self.cfg.p as u32);
        let st = expand_filtered(
            self.db,
            &mut root,
            self.lambda,
            &mut self.scratch,
            &mut self.stack,
            |i| i % p == rank,
        );
        self.work_units += st.units();
        // Count the depth-1 closed sets now so the barrier can seed λ > 1
        // (§4.5). They are *not* re-counted when popped in Main: mark them
        // by recording here and visiting only deeper nodes… simpler: record
        // now, and pop-time recording skips depth-1 by clearing a flag.
        // We instead record at pop like every other node — the preprocess
        // hist sent up the tree is a *copy* used only to seed λ.
        let mut pre_counts = SupportHist::new(self.db.n_trans());
        for c in &self.stack {
            pre_counts.record(c.support);
        }
        let mut delta: HistDelta = Vec::new();
        for (s, &c) in pre_counts.counts().iter().enumerate() {
            if c > 0 {
                delta.push((s as u32, c));
            }
        }
        self.pre_local_done = true;
        crate::dtd::mattern::merge_hist(&mut self.pre_hist, &delta);
        let cost = if real_mode { self.real_now_ns() - t0 } else { self.units_to_ns(st.units()) };
        self.breakdown.preprocess_ns += cost;
        self.check_barrier(mb);
        cost
    }

    /// Barrier progress: when the local preprocess is done and all children
    /// reported, send up (or, at the root, seed λ and release).
    fn check_barrier(&mut self, mb: &mut dyn Mailbox) {
        if !(self.pre_local_done && self.pre_pending == 0 && self.phase != Phase::Main) {
            return;
        }
        if self.cfg.rank == 0 {
            // Seed λ from the depth-1 histogram (Phase1 only).
            if let Some(rule) = &self.rule {
                let mut h = SupportHist::new(self.db.n_trans());
                for &(s, c) in &self.pre_hist {
                    for _ in 0..c {
                        h.record(s);
                    }
                }
                self.lambda = rule.advance(self.lambda, |l| h.cs_ge(l));
            }
            let lambda = self.lambda;
            for c in self.dtd.tree().children() {
                self.send_ctrl(mb, c, Msg::PreDown { lambda });
            }
            self.phase = Phase::Main;
        } else {
            let parent = self.dtd.tree().parent().unwrap();
            let hist = std::mem::take(&mut self.pre_hist);
            self.send_ctrl(mb, parent, Msg::PreUp { hist });
            self.phase = Phase::AwaitBarrier;
        }
    }

    /// Serve lifeline requesters out of surplus (Fig. 5 `Distribute`).
    fn distribute(&mut self, mb: &mut dyn Mailbox) -> u64 {
        let mut cost = 0u64;
        while self.stack.len() >= 2 && !self.incoming_lifelines.is_empty() {
            let dst = self.incoming_lifelines.remove(0);
            cost += self.give_half(mb, dst);
        }
        cost
    }

    /// Split the bottom half of the stack (oldest, largest subtrees) and
    /// GIVE it away. Returns the (virtual) cost.
    fn give_half(&mut self, mb: &mut dyn Mailbox, dst: usize) -> u64 {
        let n = self.stack.len() / 2;
        debug_assert!(n >= 1);
        let tasks: Vec<WireTask> = self
            .stack
            .drain(..n)
            .map(|mut t| {
                t.strip_for_wire();
                WireTask { items: t.items, core: t.core, support: t.support }
            })
            .collect();
        self.comm.gives += 1;
        self.comm.tasks_shipped += tasks.len() as u64;
        self.trace_event(EventKind::StealGive { dst: dst as u32, tasks: tasks.len() as u32 });
        let cost_units: u64 = 50 * tasks.len() as u64;
        self.send_basic(mb, dst, BasicKind::Give { tasks });
        let c = self.units_to_ns(cost_units).max(300);
        self.breakdown.probe_ns += c;
        c
    }

    /// Start the steal sequence (stack just emptied): `w` random steals,
    /// awaited one at a time; then lifelines. A world with no possible
    /// victim (`random_victim` → `None`) skips straight to lifelines.
    fn begin_steal(&mut self, mb: &mut dyn Mailbox) -> StealState {
        if self.cfg.w > 0 {
            if let Some(victim) = self.lifelines.random_victim(&mut self.rng) {
                self.comm.steal_requests += 1;
                self.trace_event(EventKind::StealRequest { dst: victim as u32, lifeline: false });
                self.send_basic(mb, victim, BasicKind::Request { lifeline: false });
                return StealState::AwaitReply { tries: 1 };
            }
        }
        self.post_lifelines(mb)
    }

    /// Send lifeline requests to all not-yet-activated lifelines, then idle.
    fn post_lifelines(&mut self, mb: &mut dyn Mailbox) -> StealState {
        for j in 0..self.lifelines.z() {
            if !self.activated[j] {
                self.activated[j] = true;
                let dst = self.lifelines.neighbors()[j];
                self.comm.steal_requests += 1;
                self.trace_event(EventKind::StealRequest { dst: dst as u32, lifeline: true });
                self.send_basic(mb, dst, BasicKind::Request { lifeline: true });
            }
        }
        StealState::LifelinesOut
    }

    // ---- message handling (Fig. 5 `Probe`) ------------------------------

    fn handle(&mut self, mb: &mut dyn Mailbox, src: usize, msg: Msg, now_ns: u64) {
        match msg {
            Msg::Basic { stamp, kind } => {
                self.dtd.on_basic_recv(stamp);
                match kind {
                    BasicKind::Request { lifeline } => self.on_request(mb, src, lifeline),
                    BasicKind::Reject { lifeline } => self.on_reject(mb, lifeline),
                    BasicKind::Give { tasks } => self.on_give(src, tasks),
                }
            }
            Msg::WaveDown { t, lambda } => {
                self.trace_event(EventKind::WaveArrive { t: t as u32, up: false });
                self.lambda = self.lambda.max(lambda);
                let idle = self.idle_vote();
                let hist = self.drain_wave_delta();
                let mut out = Vec::new();
                self.dtd.on_wave_down(t, lambda, idle, hist, &mut out);
                for (dst, m) in out {
                    self.send_ctrl(mb, dst, m);
                }
            }
            Msg::WaveUp { t, count, invalid, all_idle, hist } => {
                self.trace_event(EventKind::WaveArrive { t: t as u32, up: true });
                let mut out = Vec::new();
                let oc = self.dtd.on_wave_up(t, count, invalid, all_idle, hist, &mut out);
                for (dst, m) in out {
                    self.send_ctrl(mb, dst, m);
                }
                if let WaveOutcome::Complete { count, invalid, all_idle, hist } = oc {
                    self.on_wave_complete(mb, count, invalid, all_idle, hist, now_ns);
                }
            }
            Msg::PreUp { hist } => {
                debug_assert!(self.pre_pending > 0);
                self.pre_pending -= 1;
                crate::dtd::mattern::merge_hist(&mut self.pre_hist, &hist);
                self.check_barrier(mb);
            }
            Msg::PreDown { lambda } => {
                self.lambda = self.lambda.max(lambda);
                let lam = self.lambda;
                for c in self.dtd.tree().children() {
                    self.send_ctrl(mb, c, Msg::PreDown { lambda: lam });
                }
                self.phase = Phase::Main;
            }
            Msg::Finish => {
                self.phase = Phase::Done;
            }
        }
    }

    fn on_request(&mut self, mb: &mut dyn Mailbox, src: usize, lifeline: bool) {
        // Keep at least one node for ourselves; GIVE only from surplus.
        if self.cfg.steal && self.stack.len() >= 2 && self.phase == Phase::Main {
            self.give_half(mb, src);
        } else if lifeline {
            // Record for deferred distribution; echo a lifeline REJECT
            // (informational — the thief keeps the lifeline activated).
            if !self.incoming_lifelines.contains(&src) {
                self.incoming_lifelines.push(src);
            }
            self.comm.rejects += 1;
            self.trace_event(EventKind::StealReject { src: src as u32, lifeline: true });
            self.send_basic(mb, src, BasicKind::Reject { lifeline: true });
        } else {
            self.comm.rejects += 1;
            self.trace_event(EventKind::StealReject { src: src as u32, lifeline: false });
            self.send_basic(mb, src, BasicKind::Reject { lifeline: false });
        }
    }

    fn on_reject(&mut self, mb: &mut dyn Mailbox, lifeline: bool) {
        if lifeline {
            return; // lifeline recorded at the victim; stay registered
        }
        if let StealState::AwaitReply { tries } = self.steal_state {
            if !self.stack.is_empty() {
                self.steal_state = StealState::HaveWork;
            } else if tries < self.cfg.w {
                if let Some(victim) = self.lifelines.random_victim(&mut self.rng) {
                    self.comm.steal_requests += 1;
                    self.trace_event(EventKind::StealRequest {
                        dst: victim as u32,
                        lifeline: false,
                    });
                    self.send_basic(mb, victim, BasicKind::Request { lifeline: false });
                    self.steal_state = StealState::AwaitReply { tries: tries + 1 };
                } else {
                    self.steal_state = self.post_lifelines(mb);
                }
            } else {
                self.steal_state = self.post_lifelines(mb);
            }
        }
    }

    fn on_give(&mut self, src: usize, tasks: Vec<WireTask>) {
        self.trace_event(EventKind::StealRecv { src: src as u32, tasks: tasks.len() as u32 });
        for t in tasks {
            self.stack.push(SearchNode {
                items: t.items,
                core: t.core,
                support: t.support,
                occ: None,
            });
        }
        if let Some(j) = self.lifelines.index_of(src) {
            self.activated[j] = false;
        }
        self.steal_state = StealState::HaveWork;
    }

    // ---- root wave handling ---------------------------------------------

    fn start_wave(&mut self, mb: &mut dyn Mailbox, now_ns: u64) {
        let idle = self.idle_vote();
        let hist = self.drain_wave_delta();
        let lambda = self.lambda;
        let mut out = Vec::new();
        let oc = self.dtd.initiate_wave(lambda, idle, hist, &mut out);
        self.wave_in_flight = true;
        for (dst, m) in out {
            self.send_ctrl(mb, dst, m);
        }
        if let WaveOutcome::Complete { count, invalid, all_idle, hist } = oc {
            // Single-process world: the wave completes synchronously.
            self.on_wave_complete(mb, count, invalid, all_idle, hist, now_ns);
        }
    }

    fn on_wave_complete(
        &mut self,
        mb: &mut dyn Mailbox,
        count: i64,
        invalid: bool,
        all_idle: bool,
        hist: HistDelta,
        now_ns: u64,
    ) {
        debug_assert_eq!(self.cfg.rank, 0);
        self.wave_in_flight = false;
        for &(s, c) in &hist {
            for _ in 0..c {
                self.root_hist.record(s);
            }
        }
        if let Some(rule) = &self.rule {
            self.lambda = rule.advance(self.lambda, |l| self.root_hist.cs_ge(l));
        }
        if count == 0 && !invalid && all_idle && self.idle_vote() {
            for dst in 1..self.cfg.p {
                self.send_ctrl(mb, dst, Msg::Finish);
            }
            self.phase = Phase::Done;
        } else {
            self.next_wave_at = now_ns + self.cfg.dtd_interval_ns;
        }
    }

    // ---- end-of-run accessors -------------------------------------------

    pub fn rank(&self) -> usize {
        self.cfg.rank
    }

    pub fn hist(&self) -> &SupportHist {
        &self.local_hist
    }

    pub fn closed_count(&self) -> u64 {
        self.closed_count
    }

    pub fn work_units(&self) -> u64 {
        self.work_units
    }

    pub fn lambda(&self) -> u32 {
        self.lambda
    }

    pub fn stack_len(&self) -> usize {
        self.stack.len()
    }

    /// Non-draining custody snapshot: up to `max` roots from the *bottom*
    /// of the stack (oldest, largest subtrees — the same end `give_half`
    /// ships), serialized exactly as a GIVE would ship them. The process
    /// engine sends these to the hub in periodic CHECKPOINT frames
    /// (DESIGN.md §12) so a crash report can say what the rank was holding.
    pub fn stack_roots(&self, max: usize) -> Vec<WireTask> {
        self.stack
            .iter()
            .take(max)
            .map(|t| WireTask { items: t.items.clone(), core: t.core, support: t.support })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::Item;
    use crate::fabric::sim::SimMailbox;

    fn tiny_db() -> Database {
        let trans: Vec<Vec<Item>> = (0..16)
            .map(|t| (0..8).filter(|i| (t + i) % 3 != 0).map(|i| i as Item).collect())
            .collect();
        let labels: Vec<bool> = (0..16).map(|t| t < 5).collect();
        Database::from_transactions(8, &trans, &labels)
    }

    #[test]
    fn preprocess_partitions_items_mod_p() {
        let db = tiny_db();
        let p = 3;
        let mut stacks: Vec<Vec<i64>> = Vec::new();
        for rank in 0..p {
            let cfg = WorkerConfig::paper_defaults(rank, p, RunMode::Count { min_sup: 1 }, 7);
            let mut w = Worker::new(&db, cfg);
            let mut mb = SimMailbox::new(rank, p);
            // first poll runs the depth-1 preprocess
            let _ = w.poll(&mut mb, 0);
            stacks.push((0..w.stack_len()).map(|_| 0).collect());
            // verify by draining GIVE-able state: check via stack_len only;
            // the partition property is asserted through expand_filtered in
            // do_preprocess — each child core ≡ rank (mod p).
            assert!(w.stack_len() <= db.n_items());
        }
        // every depth-1 child is owned by exactly one rank
        let total: usize = stacks.iter().map(Vec::len).sum();
        assert!(total > 0);
    }

    #[test]
    fn request_to_empty_worker_is_rejected_and_lifeline_recorded() {
        let db = tiny_db();
        // rank 1 of 4, no preprocess → empty stack in Main phase
        let cfg = WorkerConfig {
            preprocess: false,
            ..WorkerConfig::paper_defaults(1, 4, RunMode::Count { min_sup: 1 }, 3)
        };
        let mut w = Worker::new(&db, cfg);
        let mut mb = SimMailbox::new(1, 4);
        // a random request: immediate reject (not lifeline)
        let random_req = Msg::Basic { stamp: 0, kind: BasicKind::Request { lifeline: false } };
        mb.inbox.push_back((2, random_req));
        let _ = w.poll(&mut mb, 0);
        let rejects: Vec<_> = mb
            .outbox
            .iter()
            .filter(|(dst, m)| {
                *dst == 2
                    && matches!(m, Msg::Basic { kind: BasicKind::Reject { lifeline: false }, .. })
            })
            .collect();
        assert_eq!(rejects.len(), 1, "random request must be rejected: {:?}", mb.outbox);
        mb.outbox.clear();
        // a lifeline request: rejected with the lifeline echo + recorded
        let lifeline_req = Msg::Basic { stamp: 0, kind: BasicKind::Request { lifeline: true } };
        mb.inbox.push_back((3, lifeline_req));
        let _ = w.poll(&mut mb, 1);
        assert!(mb.outbox.iter().any(|(dst, m)| *dst == 3
            && matches!(m, Msg::Basic { kind: BasicKind::Reject { lifeline: true }, .. })));
        assert!(w.incoming_lifelines.contains(&3));
    }

    #[test]
    fn give_merges_tasks_and_clears_lifeline() {
        let db = tiny_db();
        let cfg = WorkerConfig {
            preprocess: false,
            ..WorkerConfig::paper_defaults(1, 4, RunMode::Count { min_sup: 1 }, 3)
        };
        let mut w = Worker::new(&db, cfg);
        let mut mb = SimMailbox::new(1, 4);
        let ll0 = w.lifelines.neighbors()[0];
        w.activated[0] = true;
        mb.inbox.push_back((
            ll0,
            Msg::Basic {
                stamp: 0,
                kind: BasicKind::Give {
                    tasks: vec![WireTask { items: vec![0], core: 0, support: 10 }],
                },
            },
        ));
        let _ = w.poll(&mut mb, 0);
        assert!(!w.activated[0], "GIVE from a lifeline must deactivate it");
        // the shipped task is either still stacked or already expanded —
        // the worker must have counted it as work either way
        assert!(w.stack_len() > 0 || w.closed_count() > 0);
    }

    #[test]
    fn stack_roots_snapshot_is_non_draining() {
        let db = tiny_db();
        let cfg = WorkerConfig::paper_defaults(0, 2, RunMode::Count { min_sup: 1 }, 7);
        let mut w = Worker::new(&db, cfg);
        let mut mb = SimMailbox::new(0, 2);
        let _ = w.poll(&mut mb, 0); // depth-1 preprocess fills the stack
        let before = w.stack_len();
        assert!(before > 0);
        let roots = w.stack_roots(2);
        assert_eq!(roots.len(), before.min(2));
        assert_eq!(w.stack_len(), before, "snapshot must not drain the stack");
        // Bottom-of-stack order, same serialization a GIVE would use.
        assert_eq!(roots[0].items, w.stack[0].items);
        assert_eq!(roots[0].support, w.stack[0].support);
        assert!(w.stack_roots(0).is_empty());
    }

    #[test]
    fn single_process_terminates_by_itself() {
        let db = tiny_db();
        let cfg = WorkerConfig {
            preprocess: false,
            ..WorkerConfig::paper_defaults(0, 1, RunMode::Count { min_sup: 1 }, 3)
        };
        let mut w = Worker::new(&db, cfg);
        let mut mb = SimMailbox::new(0, 1);
        let mut now = 0u64;
        for _ in 0..10_000 {
            match w.poll(&mut mb, now) {
                Poll::Finished => return,
                Poll::Busy { cost_ns } => now += cost_ns.max(1),
                Poll::Idle { wake_at } => now = wake_at.unwrap_or(now + 1000).max(now + 1),
            }
            // single proc: no outbox traffic expected except none
            assert!(mb.outbox.is_empty());
        }
        panic!("worker never finished");
    }
}
