//! The `parlamp serve` daemon (DESIGN.md §9).
//!
//! One process owns a warm [`ProcessFleet`] for its whole lifetime and
//! answers job frames over a stream socket — Unix-domain by default, TCP
//! when `--endpoint tcp:host:port` says so (DESIGN.md §11):
//!
//! - a **listener thread** accepts client connections and spawns one
//!   handler thread per connection;
//! - handler threads translate frames into operations on the shared state
//!   (submit → job table + FIFO queue, status/result/cancel → job table)
//!   and block `RESULT` replies until the job is terminal;
//! - the **scheduler** (the thread that called [`serve`]) pops the queue
//!   and runs one mining job at a time across the warm fleet via
//!   [`Coordinator::run_on_fleet`] — re-shipping the database to the
//!   workers only when its digest changes, and skipping the fleet entirely
//!   on a result-cache hit.
//!
//! Shutdown (a `SHUTDOWN` frame or `SIGTERM`/`SIGINT`) is graceful: new
//! submissions are rejected, the queue drains, the fleet gets its `BYE`,
//! and the socket is unlinked before [`serve`] returns.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::Duration;

use anyhow::{Context as _, Result};

use crate::coordinator::Coordinator;
use crate::net::{Endpoint, Listener, Stream};
use crate::par::{DataPlane, PendingFleet, ProcessConfig, ProcessFleet};
use crate::util::fault::FaultPlan;
use crate::util::sig;
use crate::wire::service::{JobOutcome, JobSpec, JobState};
use crate::wire::{read_frame, write_frame, Frame};

use super::cache::{CacheKey, ResultCache};
use super::queue::JobQueue;

/// Knobs of one daemon instance.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Where to listen (`unix:<path>` or `tcp:<host>:<port>`). A Unix
    /// socket is created at startup and unlinked at shutdown, and the
    /// daemon refuses to start if the path already exists; a TCP listener
    /// leaves nothing on disk.
    pub listen: Endpoint,
    /// Warm fleet size (worker processes).
    pub procs: usize,
    /// Result-cache capacity (entries).
    pub cache_cap: usize,
    /// Worker executable override (tests; `None` = this binary).
    pub worker_exe: Option<PathBuf>,
    /// Fleet spawn/handshake timeout.
    pub spawn_timeout: Duration,
    /// Data plane of the warm fleet (`--data-plane hub|mesh`, DESIGN.md
    /// §10). A daemon property like the fleet size: the mesh peer links
    /// are opened lazily and then kept warm across jobs, so a stream of
    /// steal-heavy jobs pays the connect cost once.
    pub data_plane: DataPlane,
    /// Where the fleet *hub* listens (`--transport tcp` maps to
    /// `Some(tcp:127.0.0.1:0)`); `None` = a fresh per-fleet Unix socket.
    pub fleet_listen: Option<Endpoint>,
    /// Remote attach mode (`--hosts`): the daemon spawns no local workers
    /// and instead prints join commands for `len()` externally-launched
    /// ones (see [`crate::par::engine_process`]).
    pub remote_workers: Option<Vec<Endpoint>>,
    /// Deterministic fault injection (`--fault-inject`, DESIGN.md §12):
    /// kill the named worker at the planned point of the fleet's lifetime.
    /// The chaos suite uses it to prove an in-flight job survives a worker
    /// death; the respawned replacement never inherits the plan.
    pub fault: Option<FaultPlan>,
}

impl ServeConfig {
    pub fn new(listen: Endpoint, procs: usize) -> ServeConfig {
        ServeConfig {
            listen,
            procs,
            cache_cap: 32,
            worker_exe: None,
            spawn_timeout: Duration::from_secs(30),
            data_plane: DataPlane::Mesh,
            fleet_listen: None,
            remote_workers: None,
            fault: None,
        }
    }
}

/// A job's lifecycle record. The spec (and its database) is dropped the
/// moment the scheduler takes the job, so queued-but-not-yet-run jobs are
/// the only ones holding database memory.
enum Record {
    Queued { spec: Box<JobSpec>, key: CacheKey },
    Running,
    Done { outcome: JobOutcome },
    Failed { reason: String },
    Cancelled,
}

/// How many *terminal* job records (done/failed/cancelled) the daemon
/// retains for STATUS/RESULT queries. Older ones are evicted oldest-first
/// and report `not found` afterwards — without a bound, a long-running
/// daemon would leak one record (outcome included) per submission forever.
const JOB_HISTORY_CAP: usize = 1024;

struct Inner {
    next_id: u64,
    queue: JobQueue,
    jobs: HashMap<u64, Record>,
    /// Terminal job ids, oldest first, for [`JOB_HISTORY_CAP`] eviction.
    finished: std::collections::VecDeque<u64>,
    cache: ResultCache,
    /// Shutdown requested: reject new submissions, finish the queue, exit.
    draining: bool,
    /// The scheduler has exited (result waiters must not block forever).
    done: bool,
    jobs_mined: u64,
}

impl Inner {
    /// Record a job's terminal state and evict the oldest terminal records
    /// beyond [`JOB_HISTORY_CAP`]. Queued/running jobs are never evicted.
    fn finish(&mut self, id: u64, record: Record) {
        self.jobs.insert(id, record);
        self.finished.push_back(id);
        while self.finished.len() > JOB_HISTORY_CAP {
            if let Some(old) = self.finished.pop_front() {
                self.jobs.remove(&old);
            }
        }
    }
}

struct Shared {
    inner: Mutex<Inner>,
    /// Signals queue arrivals (scheduler) and job completions (waiters).
    wake: Condvar,
}

impl Shared {
    fn lock(&self) -> MutexGuard<'_, Inner> {
        self.inner.lock().expect("service state lock")
    }
}

/// Unlink the service socket when the daemon exits, however it exits.
/// Transport-aware: only a Unix endpoint leaves a filesystem name behind;
/// for TCP there is nothing to unlink, so the guard is a no-op and a
/// restart can never fail on a bogus stale-path check.
struct SocketGuard(Endpoint);

impl Drop for SocketGuard {
    fn drop(&mut self) {
        if let Some(path) = self.0.unix_path() {
            let _ = std::fs::remove_file(path);
        }
    }
}

/// Spawn (or remote-attach) the daemon's warm fleet. In remote attach
/// mode the per-rank join commands are printed *before* the blocking wait,
/// so the operator can start the workers on their hosts.
fn spawn_fleet(fleet_cfg: &ProcessConfig) -> Result<ProcessFleet> {
    let pending = ProcessFleet::bind(fleet_cfg).context("bind fleet hub")?;
    if let Some(hosts) = &fleet_cfg.remote_workers {
        print_join_commands(&pending, hosts);
    }
    pending.await_workers().context("assemble warm worker fleet")
}

/// Print one copy-pasteable `parlamp __worker` join command per rank —
/// shared by `serve` and the `lamp --hosts` launcher path.
pub fn print_join_commands(pending: &PendingFleet, hosts: &[Endpoint]) {
    let exe = std::env::current_exe()
        .map(|p| p.to_string_lossy().into_owned())
        .unwrap_or_else(|_| "parlamp".into());
    println!(
        "fleet hub listening at {} ({} remote worker(s) expected)",
        pending.endpoint(),
        hosts.len()
    );
    println!("start each worker on its host:");
    for (rank, peer) in hosts.iter().enumerate() {
        println!("JOIN[{rank}]: {}", pending.join_command(&exe, rank, Some(peer)));
    }
}

/// Run the daemon: spawn the fleet, listen on `cfg.listen`, schedule jobs
/// until a `SHUTDOWN` frame or `SIGTERM`/`SIGINT` drains the queue.
/// Returns after the fleet was dismissed and any Unix socket unlinked.
pub fn serve(cfg: &ServeConfig) -> Result<()> {
    // SIGTERM/SIGINT latch into an atomic flag the scheduler polls; the
    // worker processes ignore terminal SIGINT themselves (see util::sig),
    // so a Ctrl-C drain finishes the in-flight job instead of killing the
    // fleet under it.
    sig::install_terminate_latch();
    let fleet_cfg = ProcessConfig {
        worker_exe: cfg.worker_exe.clone(),
        spawn_timeout: cfg.spawn_timeout,
        data_plane: cfg.data_plane,
        listen: cfg.fleet_listen.clone(),
        remote_workers: cfg.remote_workers.clone(),
        fault: cfg.fault,
        ..ProcessConfig::paper_defaults(cfg.procs, 2015)
    };
    // Fleet first: a daemon that cannot mine should fail before it starts
    // accepting submissions.
    let mut fleet = Some(spawn_fleet(&fleet_cfg)?);
    println!(
        "parlamp serve: fleet of {} worker processes warm ({} data plane)",
        fleet_cfg.world_size(),
        cfg.data_plane.name()
    );

    if let Some(path) = cfg.listen.unix_path() {
        // Refuse a stale path loudly instead of silently stealing it; a
        // TCP bind gets the same protection from the OS (AddrInUse).
        if path.exists() {
            anyhow::bail!(
                "service socket {} already exists (stale socket from a dead daemon? \
                 remove it first)",
                path.display()
            );
        }
    }
    let listener = Listener::bind(&cfg.listen)
        .with_context(|| format!("bind service endpoint {}", cfg.listen))?;
    let _socket_guard = SocketGuard(cfg.listen.clone());
    let bound = listener.local_endpoint().context("resolve service endpoint")?;
    listener.set_nonblocking(true).context("set service listener non-blocking")?;
    println!("parlamp serve: listening on {bound}");

    let shared = Arc::new(Shared {
        inner: Mutex::new(Inner {
            next_id: 1,
            queue: JobQueue::new(),
            jobs: HashMap::new(),
            finished: std::collections::VecDeque::new(),
            cache: ResultCache::new(cfg.cache_cap),
            draining: false,
            done: false,
            jobs_mined: 0,
        }),
        wake: Condvar::new(),
    });

    // Listener thread: accept until the scheduler is done.
    let accept_shared = Arc::clone(&shared);
    let listener_thread = std::thread::spawn(move || loop {
        if accept_shared.lock().done {
            return;
        }
        match listener.accept() {
            Ok(stream) => {
                if stream.set_nonblocking(false).is_err() {
                    continue;
                }
                let shared = Arc::clone(&accept_shared);
                std::thread::spawn(move || client_loop(stream, &shared));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(20));
            }
            // Transient accept failures (ECONNABORTED from a client that
            // vanished mid-handshake, EMFILE under fd pressure) must not
            // kill the accept loop — a daemon that silently stops
            // answering is worse than a noisy retry.
            Err(e) => {
                eprintln!("parlamp serve: accept error (retrying): {e}");
                std::thread::sleep(Duration::from_millis(100));
            }
        }
    });

    // Scheduler: one mining job at a time on this thread.
    scheduler_loop(&shared, &mut fleet, &fleet_cfg);

    // Drained. Release waiters, stop the listener, dismiss the fleet.
    {
        let mut inner = shared.lock();
        inner.done = true;
        let (hits, misses) = inner.cache.stats();
        println!(
            "parlamp serve: drained ({} jobs mined, cache {hits} hits / {misses} misses)",
            inner.jobs_mined
        );
    }
    shared.wake.notify_all();
    let _ = listener_thread.join();
    if let Some(fleet) = fleet.take() {
        fleet.shutdown().context("dismiss warm fleet")?;
    }
    Ok(())
}

fn scheduler_loop(
    shared: &Arc<Shared>,
    fleet: &mut Option<ProcessFleet>,
    fleet_cfg: &ProcessConfig,
) {
    loop {
        let next = {
            let mut inner = shared.lock();
            if sig::terminate_requested() && !inner.draining {
                inner.draining = true;
                println!("parlamp serve: signal received, draining queue");
            }
            match inner.queue.pop() {
                Some(id) => Some(id),
                None if inner.draining => break,
                None => None,
            }
        };
        let Some(id) = next else {
            // Idle: sleep until a submission (or poll the signal latch).
            let inner = shared.lock();
            drop(
                shared
                    .wake
                    .wait_timeout(inner, Duration::from_millis(200))
                    .expect("service state lock"),
            );
            continue;
        };

        // Take the job's spec and mark it running. (A popped id is always
        // `Queued`: CANCEL only flips jobs it removed from the queue.)
        let Some((spec, key)) = ({
            let mut inner = shared.lock();
            match inner.jobs.insert(id, Record::Running) {
                Some(Record::Queued { spec, key }) => Some((spec, key)),
                stale => {
                    // Defensive: restore whatever was there and skip.
                    if let Some(r) = stale {
                        inner.jobs.insert(id, r);
                    }
                    None
                }
            }
        }) else {
            continue;
        };

        // Schedule-time cache probe: an identical job may have finished
        // while this one waited in the queue.
        let cached = {
            let mut inner = shared.lock();
            inner.cache.get(&key).map(|o| o.as_ref().clone())
        };
        if let Some(outcome) = cached {
            shared.lock().finish(id, Record::Done { outcome });
            shared.wake.notify_all();
            continue;
        }

        // Mine. A failed fleet is poisoned: drop it (children die) and
        // respawn for the next job.
        let outcome = mine(fleet, fleet_cfg, &spec);
        {
            let mut inner = shared.lock();
            match outcome {
                Ok(run) => {
                    inner.jobs_mined += 1;
                    let outcome = JobOutcome::from_run(&run, false);
                    inner.cache.insert(key, &run);
                    inner.finish(id, Record::Done { outcome });
                }
                Err(e) => {
                    inner.finish(id, Record::Failed { reason: format!("{e:#}") });
                }
            }
        }
        shared.wake.notify_all();
    }
}

fn mine(
    fleet: &mut Option<ProcessFleet>,
    fleet_cfg: &ProcessConfig,
    spec: &JobSpec,
) -> Result<crate::coordinator::CoordinatorRun> {
    if fleet.is_none() {
        *fleet = Some(spawn_fleet(fleet_cfg).context("respawn worker fleet")?);
    }
    let f = fleet.as_mut().expect("fleet just ensured");
    let coord = Coordinator::new(spec.alpha).with_glb(spec.glb).with_screen(spec.screen);
    match coord.run_on_fleet(&spec.db, f, spec.seed) {
        Ok(run) => Ok(run),
        Err(e) => {
            *fleet = None; // poisoned: kill-on-drop, respawn lazily
            Err(e)
        }
    }
}

/// One connected client: serve frames until EOF (or its `SHUTDOWN` ack).
fn client_loop(mut stream: Stream, shared: &Arc<Shared>) {
    loop {
        let frame = match read_frame(&mut stream) {
            Ok(Some(frame)) => frame,
            Ok(None) => return, // client gone
            // A malformed or version-mismatched frame gets one clear error
            // reply (the wire versioning promise) before the connection
            // closes — after a framing error the stream cannot be resynced.
            Err(e) => {
                eprintln!("parlamp serve: bad client frame: {e:#}");
                let _ = write_frame(
                    &mut stream,
                    &Frame::Status {
                        job_id: 0,
                        report: Some(JobState::Failed { reason: format!("bad frame: {e:#}") }),
                    },
                );
                return;
            }
        };
        let last = matches!(frame, Frame::Shutdown);
        let reply = handle(shared, frame);
        if write_frame(&mut stream, &reply).is_err() {
            return;
        }
        if last {
            return;
        }
    }
}

fn handle(shared: &Arc<Shared>, frame: Frame) -> Frame {
    match frame {
        Frame::Submit(spec) => submit(shared, spec),
        Frame::Status { job_id, .. } => {
            let inner = shared.lock();
            Frame::Status { job_id, report: Some(state_of(&inner, job_id)) }
        }
        Frame::JobResult { job_id, .. } => wait_result(shared, job_id),
        Frame::Cancel { job_id } => {
            let mut inner = shared.lock();
            if inner.queue.cancel(job_id) {
                inner.finish(job_id, Record::Cancelled);
            }
            Frame::Status { job_id, report: Some(state_of(&inner, job_id)) }
        }
        Frame::Shutdown => {
            {
                let mut inner = shared.lock();
                if !inner.draining {
                    inner.draining = true;
                    println!("parlamp serve: SHUTDOWN received, draining queue");
                }
            }
            shared.wake.notify_all();
            Frame::Shutdown
        }
        other => Frame::Status {
            job_id: 0,
            report: Some(JobState::Failed {
                reason: format!("unexpected {} frame on the service socket", other.name()),
            }),
        },
    }
}

fn submit(shared: &Arc<Shared>, spec: Box<JobSpec>) -> Frame {
    let key = CacheKey::new(spec.db.digest(), spec.alpha, spec.glb, spec.screen);
    let mut inner = shared.lock();
    if inner.draining {
        return Frame::Status {
            job_id: 0,
            report: Some(JobState::Failed {
                reason: "daemon is draining (shutdown in progress)".into(),
            }),
        };
    }
    let id = inner.next_id;
    inner.next_id += 1;
    // Submit-time cache probe: a repeat submission never reaches the
    // queue, let alone the workers.
    if let Some(outcome) = inner.cache.get(&key) {
        inner.finish(id, Record::Done { outcome: outcome.as_ref().clone() });
    } else {
        inner.jobs.insert(id, Record::Queued { spec, key });
        inner.queue.push(id);
        drop(inner);
        shared.wake.notify_all();
    }
    Frame::Accepted { job_id: id }
}

fn state_of(inner: &Inner, id: u64) -> JobState {
    match inner.jobs.get(&id) {
        None => JobState::NotFound,
        Some(Record::Queued { .. }) => JobState::Queued {
            position: inner.queue.position(id).unwrap_or(0) as u32,
        },
        Some(Record::Running) => JobState::Running,
        Some(Record::Done { outcome }) => JobState::Done { from_cache: outcome.from_cache },
        Some(Record::Failed { reason }) => JobState::Failed { reason: reason.clone() },
        Some(Record::Cancelled) => JobState::Cancelled,
    }
}

/// Block until `id` is terminal; reply `RESULT` for a finished job and a
/// `STATUS` report otherwise (failed, cancelled, unknown).
fn wait_result(shared: &Arc<Shared>, id: u64) -> Frame {
    let mut inner = shared.lock();
    loop {
        // Decide on an owned reply first so the `jobs` borrow ends before
        // the guard is handed to the condvar.
        let reply: Option<Frame> = match inner.jobs.get(&id) {
            Some(Record::Done { outcome }) => {
                Some(Frame::JobResult { job_id: id, report: Some(Box::new(outcome.clone())) })
            }
            Some(Record::Queued { .. } | Record::Running) if !inner.done => None,
            Some(Record::Queued { .. } | Record::Running) => Some(Frame::Status {
                job_id: id,
                report: Some(JobState::Failed {
                    reason: "daemon exited before the job finished".into(),
                }),
            }),
            _ => Some(Frame::Status { job_id: id, report: Some(state_of(&inner, id)) }),
        };
        if let Some(frame) = reply {
            return frame;
        }
        let (guard, _) = shared
            .wake
            .wait_timeout(inner, Duration::from_millis(200))
            .expect("service state lock");
        inner = guard;
    }
}
