//! Randomized equivalence suite for the reduced-database hot path (PR 3).
//!
//! The miner now expands every node against a per-node conditional
//! database (`db::ConditionalDb`: item pruning, identical-row merging,
//! adaptive dense/sparse encoding — DESIGN.md §8). These tests pin the
//! only contract that matters: the closed-set **multiset** it emits is
//! exactly the brute-force oracle's, across densities, shapes (including
//! row spaces large enough to trigger the sparse encoding), duplicated
//! transactions (forcing row merging), and minimum supports.

use parlamp::db::{Database, Item};
use parlamp::lamp::{lamp2::lamp2_serial, lamp_serial};
use parlamp::lcm::{brute_force_closed, mine_closed, Visit};
use parlamp::util::propcheck::forall;
use parlamp::util::rng::Rng;

fn random_db(
    rng: &mut Rng,
    m_lo: usize,
    m_hi: usize,
    n_lo: usize,
    n_hi: usize,
    d_lo: f64,
    d_hi: f64,
) -> Database {
    let m = m_lo + rng.index(m_hi - m_lo + 1);
    let n = n_lo + rng.index(n_hi - n_lo + 1);
    let density = d_lo + rng.f64() * (d_hi - d_lo);
    let trans: Vec<Vec<Item>> = (0..n)
        .map(|_| (0..m as Item).filter(|_| rng.bernoulli(density)).collect())
        .collect();
    let labels: Vec<bool> = (0..n).map(|_| rng.bernoulli(0.35)).collect();
    Database::from_transactions(m, &trans, &labels)
}

/// Mine with the reduced-database engine; returns the sorted closed-set
/// multiset and asserts no duplicates were emitted.
fn mine(db: &Database, min_sup: u32) -> Vec<(Vec<Item>, u32)> {
    let mut got = Vec::new();
    mine_closed(db, min_sup, |node, ms| {
        got.push((node.items.clone(), node.support));
        (Visit::Continue, ms)
    });
    got.sort();
    let mut dedup = got.clone();
    dedup.dedup();
    assert_eq!(dedup.len(), got.len(), "duplicate closed sets emitted");
    got
}

#[test]
fn dense_small_dbs_match_brute_force() {
    forall("reduced miner == brute force (dense regime)", 70, |rng| {
        let db = random_db(rng, 4, 10, 8, 28, 0.15, 0.7);
        let min_sup = 1 + rng.below(4) as u32;
        let got = mine(&db, min_sup);
        let want = brute_force_closed(&db, min_sup);
        if got != want {
            return Err(format!(
                "m={} n={} min_sup={min_sup}\n got {got:?}\nwant {want:?}",
                db.n_items(),
                db.n_trans()
            ));
        }
        Ok(())
    });
}

#[test]
fn tall_sparse_dbs_use_sparse_encoding_and_match_od_miner() {
    // The sparse id-list encoding needs > 512 *distinct* merged rows at
    // ones-per-column below rows/32 — a regime of tall, very sparse data
    // that small brute-forceable databases cannot reach (merging collapses
    // them under the dense cutoff). Construct it deterministically, verify
    // the root projection really is sparse-encoded, and use the
    // independently-implemented occurrence-deliver miner (itself
    // brute-validated on small databases) as the oracle.
    use parlamp::bits::BitVec;
    use parlamp::db::ConditionalDb;
    use parlamp::lamp::lamp2::{mine_closed_od, HorizontalDb};

    for (mul, add) in [(7usize, 3usize), (13, 5)] {
        let m = 100usize;
        let n = 1500usize;
        let trans: Vec<Vec<Item>> = (0..n)
            .map(|t| {
                let mut row = vec![
                    (t % m) as Item,
                    ((t / m * mul + t) % m) as Item,
                    ((t * mul + add) % m) as Item,
                ];
                row.sort_unstable();
                row.dedup();
                row
            })
            .collect();
        let labels: Vec<bool> = (0..n).map(|t| t % 5 == 0).collect();
        let db = Database::from_transactions(m, &trans, &labels);

        let cond = ConditionalDb::project(&db, &BitVec::ones(n), &[], -1, 1);
        assert!(cond.rows() > 512, "rows={}", cond.rows());
        assert!(!cond.is_dense(), "root projection must take the sparse encoding");

        let h = HorizontalDb::from_database(&db);
        for min_sup in [1u32, 2, 4] {
            let got = mine(&db, min_sup);
            let mut want = Vec::new();
            mine_closed_od(&h, min_sup, |items, sup, _tids, ms| {
                want.push((items.to_vec(), sup));
                (Visit::Continue, ms)
            });
            want.sort();
            assert_eq!(
                got.len(),
                want.len(),
                "mul={mul} min_sup={min_sup}: closed-set counts differ"
            );
            assert_eq!(got, want, "mul={mul} min_sup={min_sup}");
        }
    }
}

#[test]
fn duplicated_transactions_force_row_merging() {
    // Databases built from few distinct patterns repeated many times: the
    // projection merges aggressively, weights carry the true supports.
    forall("reduced miner == brute force (merged rows)", 30, |rng| {
        let m = 4 + rng.index(5);
        let n_patterns = 2 + rng.index(4);
        let patterns: Vec<Vec<Item>> = (0..n_patterns)
            .map(|_| (0..m as Item).filter(|_| rng.bernoulli(0.5)).collect())
            .collect();
        let n = 12 + rng.index(30);
        let trans: Vec<Vec<Item>> =
            (0..n).map(|_| patterns[rng.index(n_patterns)].clone()).collect();
        let labels: Vec<bool> = (0..n).map(|t| t % 2 == 0).collect();
        let db = Database::from_transactions(m, &trans, &labels);
        let min_sup = 1 + rng.below(5) as u32;
        let got = mine(&db, min_sup);
        let want = brute_force_closed(&db, min_sup);
        if got != want {
            return Err(format!("m={m} n={n} min_sup={min_sup}"));
        }
        Ok(())
    });
}

#[test]
fn degenerate_shapes() {
    // min_sup above every support → nothing but possibly the root.
    let db = Database::from_transactions(
        3,
        &[vec![0, 1], vec![1, 2], vec![0, 2]],
        &[true, false, false],
    );
    assert_eq!(mine(&db, 10), Vec::<(Vec<Item>, u32)>::new());
    // all-identical transactions: one closed set.
    let db = Database::from_transactions(
        2,
        &[vec![0, 1], vec![0, 1], vec![0, 1]],
        &[true, true, false],
    );
    assert_eq!(mine(&db, 1), vec![(vec![0, 1], 3)]);
    // single column.
    let db = Database::from_transactions(1, &[vec![0], vec![], vec![0]], &[true, false, true]);
    assert_eq!(mine(&db, 1), vec![(vec![0], 2)]);
    assert_eq!(mine(&db, 3), Vec::<(Vec<Item>, u32)>::new());
    // empty database.
    let db = Database::from_transactions(2, &[], &[]);
    assert_eq!(mine(&db, 1), Vec::<(Vec<Item>, u32)>::new());
}

#[test]
fn full_pipeline_agrees_with_occurrence_deliver_baseline() {
    // End-to-end LAMP on the reduced hot path vs the independent LAMP2
    // engine: λ*, correction factor, and the significant set must agree
    // (the paper's Table-2 cross-check, now guarding the reduction).
    forall("lamp_serial == lamp2_serial on reduced path", 20, |rng| {
        let db = random_db(rng, 4, 8, 10, 24, 0.3, 0.6);
        let a = lamp_serial(&db, 0.05);
        let b = lamp2_serial(&db, 0.05);
        if a.lambda_final != b.lambda_final
            || a.correction_factor != b.correction_factor
            || a.significant.len() != b.significant.len()
        {
            return Err(format!(
                "bitmap λ*={} k={} sig={} vs od λ*={} k={} sig={}",
                a.lambda_final,
                a.correction_factor,
                a.significant.len(),
                b.lambda_final,
                b.correction_factor,
                b.significant.len()
            ));
        }
        Ok(())
    });
}
