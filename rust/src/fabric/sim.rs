//! Discrete-event simulated network.
//!
//! The substitution for the paper's TSUBAME testbed (DESIGN.md §2): virtual
//! processes exchange messages through an event queue with a calibrated
//! latency + bandwidth model. The *protocol code is the real worker*; only
//! time is virtual, so load-balancing dynamics, steal traffic, and
//! termination behaviour are faithful at P = 1,200 on a single host.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

use super::{Mailbox, Msg};

/// Network timing model. Defaults approximate dual-rail QDR InfiniBand
/// (the paper's interconnect): ~2 µs one-way latency, 80 Gbps aggregate.
#[derive(Clone, Copy, Debug)]
pub struct NetModel {
    /// One-way message latency in nanoseconds.
    pub latency_ns: u64,
    /// Bandwidth in bytes per nanosecond (10 B/ns = 80 Gbps).
    pub bytes_per_ns: f64,
    /// Fixed per-message software overhead charged to the *receiver*'s
    /// probe time (send/recv call cost).
    pub sw_overhead_ns: u64,
}

impl Default for NetModel {
    fn default() -> Self {
        NetModel { latency_ns: 2_000, bytes_per_ns: 10.0, sw_overhead_ns: 300 }
    }
}

impl NetModel {
    /// An "Ethernet-class" model for the slow-network estimate the paper
    /// discusses in §5.2 (they could not measure one; we can simulate it).
    pub fn ethernet() -> Self {
        NetModel { latency_ns: 50_000, bytes_per_ns: 0.125, sw_overhead_ns: 3_000 }
    }

    /// Time for a message of `bytes` to reach its destination.
    pub fn transit_ns(&self, bytes: usize) -> u64 {
        self.latency_ns + (bytes as f64 / self.bytes_per_ns) as u64
    }
}

/// What happens at a virtual process.
#[derive(Clone, Debug)]
pub enum EventKind {
    /// A message arrives.
    Deliver { src: usize, msg: Msg },
    /// The process gets scheduled to run (its own continuation).
    Poll,
}

/// A scheduled event. Ordering: earliest time first, FIFO within a time
/// (the `seq` tiebreaker keeps the simulation deterministic).
#[derive(Clone, Debug)]
pub struct Event {
    pub time_ns: u64,
    pub seq: u64,
    pub dst: usize,
    pub kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.time_ns == other.time_ns && self.seq == other.seq
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.time_ns.cmp(&other.time_ns).then(self.seq.cmp(&other.seq))
    }
}

/// Deterministic future-event list.
#[derive(Default)]
pub struct EventQueue {
    heap: BinaryHeap<Reverse<Event>>,
    seq: u64,
}

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, time_ns: u64, dst: usize, kind: EventKind) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Reverse(Event { time_ns, seq, dst, kind }));
    }

    pub fn pop(&mut self) -> Option<Event> {
        self.heap.pop().map(|Reverse(e)| e)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

/// Per-process mailbox inside the simulation. The worker sees the plain
/// [`Mailbox`] surface; sends land in `outbox` and the engine turns them
/// into `Deliver` events with the [`NetModel`]'s timing.
pub struct SimMailbox {
    pub rank: usize,
    pub size: usize,
    pub inbox: VecDeque<(usize, Msg)>,
    pub outbox: Vec<(usize, Msg)>,
}

impl SimMailbox {
    pub fn new(rank: usize, size: usize) -> Self {
        SimMailbox { rank, size, inbox: VecDeque::new(), outbox: Vec::new() }
    }
}

impl Mailbox for SimMailbox {
    fn rank(&self) -> usize {
        self.rank
    }
    fn size(&self) -> usize {
        self.size
    }
    fn send(&mut self, dst: usize, msg: Msg) {
        self.outbox.push((dst, msg));
    }
    fn try_recv(&mut self) -> Option<(usize, Msg)> {
        self.inbox.pop_front()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn event_queue_orders_by_time_then_seq() {
        let mut q = EventQueue::new();
        q.push(50, 1, EventKind::Poll);
        q.push(10, 0, EventKind::Poll);
        q.push(10, 2, EventKind::Poll);
        let a = q.pop().unwrap();
        let b = q.pop().unwrap();
        let c = q.pop().unwrap();
        assert_eq!((a.time_ns, a.dst), (10, 0));
        assert_eq!((b.time_ns, b.dst), (10, 2)); // FIFO within equal time
        assert_eq!((c.time_ns, c.dst), (50, 1));
        assert!(q.pop().is_none());
    }

    #[test]
    fn net_model_transit_includes_bandwidth() {
        let m = NetModel::default();
        assert_eq!(m.transit_ns(0), 2_000);
        assert_eq!(m.transit_ns(10_000), 2_000 + 1_000);
        let e = NetModel::ethernet();
        assert!(e.transit_ns(1_000) > m.transit_ns(1_000) * 10);
    }

    #[test]
    fn sim_mailbox_buffers() {
        let mut mb = SimMailbox::new(1, 4);
        assert_eq!(mb.rank(), 1);
        assert_eq!(mb.size(), 4);
        mb.send(2, Msg::Finish);
        assert_eq!(mb.outbox.len(), 1);
        mb.inbox.push_back((0, Msg::Finish));
        assert_eq!(mb.try_recv(), Some((0, Msg::Finish)));
        assert!(mb.try_recv().is_none());
    }
}
