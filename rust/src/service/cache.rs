//! The daemon's bounded in-memory result cache.
//!
//! Keyed by `(database digest, α, GlbParams, screen mode)` — everything
//! that determines a job's *result*. The steal-randomness seed is
//! deliberately excluded: results are seed-invariant (only communication
//! and timing statistics differ), so two submissions that differ only in
//! seed are the same computation. Eviction is least-recently-*used* (a hit
//! refreshes the entry), capacity is fixed at construction, and a repeat
//! submission that hits returns the stored result without the workers
//! receiving a single frame.
//!
//! What is stored is the wire-ready [`JobOutcome`] view of the finished
//! [`CoordinatorRun`] (λ*, correction factor, phase-2 histogram,
//! significant set, makespans), prebuilt with `from_cache = true` and held
//! behind an [`Arc`]: a hit under the daemon's global state lock is one
//! `Arc` clone — never a `CoordinatorRun` deep copy or a histogram
//! rebuild, and entries do not retain the run's per-rank breakdowns or
//! dense histograms that nothing on the serving path reads.

use std::collections::HashMap;
use std::sync::Arc;

use crate::coordinator::{CoordinatorRun, GlbParams, ScreenMode};
use crate::wire::service::JobOutcome;

/// What determines a mining job's result (DESIGN.md §9).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct CacheKey {
    /// [`crate::db::Database::digest`] of the submitted database.
    pub digest: u64,
    /// `f64::to_bits` of α (bit-exact: 0.05 and 0.05000…1 are different
    /// computations, and NaN never reaches here — the CLI parses α).
    pub alpha_bits: u64,
    pub glb: GlbParams,
    pub screen: ScreenMode,
}

impl CacheKey {
    pub fn new(digest: u64, alpha: f64, glb: GlbParams, screen: ScreenMode) -> CacheKey {
        CacheKey { digest, alpha_bits: alpha.to_bits(), glb, screen }
    }
}

/// Bounded LRU map from [`CacheKey`] to the finished result.
#[derive(Debug)]
pub struct ResultCache {
    cap: usize,
    map: HashMap<CacheKey, Arc<JobOutcome>>,
    /// Keys from least- to most-recently used.
    order: Vec<CacheKey>,
    hits: u64,
    misses: u64,
}

impl ResultCache {
    /// A cache holding at most `cap` results (`cap` ≥ 1).
    pub fn new(cap: usize) -> ResultCache {
        ResultCache { cap: cap.max(1), map: HashMap::new(), order: Vec::new(), hits: 0, misses: 0 }
    }

    fn touch(&mut self, key: &CacheKey) {
        if let Some(i) = self.order.iter().position(|k| k == key) {
            let k = self.order.remove(i);
            self.order.push(k);
        }
    }

    /// Look up a result, refreshing its recency on a hit.
    pub fn get(&mut self, key: &CacheKey) -> Option<Arc<JobOutcome>> {
        match self.map.get(key).cloned() {
            Some(outcome) => {
                self.hits += 1;
                self.touch(key);
                Some(outcome)
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Store a finished run (its cached wire outcome is built here, once,
    /// with `from_cache = true`), evicting the least-recently-used entry
    /// at capacity.
    pub fn insert(&mut self, key: CacheKey, run: &CoordinatorRun) {
        self.insert_outcome(key, Arc::new(JobOutcome::from_run(run, true)));
    }

    /// Store an already-built outcome — the persistent store's warm-load
    /// path ([`super::store`]), where the wire view was decoded from disk
    /// rather than built from a live run. Counts neither hit nor miss.
    pub fn insert_outcome(&mut self, key: CacheKey, outcome: Arc<JobOutcome>) {
        if self.map.insert(key, outcome).is_some() {
            self.touch(&key);
            return;
        }
        self.order.push(key);
        while self.map.len() > self.cap {
            let evict = self.order.remove(0);
            self.map.remove(&evict);
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// `(hits, misses)` since construction.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits, self.misses)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Backend, Coordinator};
    use crate::datagen::{generate_gwas, GwasSpec};

    fn tiny_run() -> CoordinatorRun {
        let spec = GwasSpec { n_snps: 40, n_individuals: 30, n_pos: 8, ..GwasSpec::small(3) };
        let (db, _) = generate_gwas(&spec);
        Coordinator::new(0.05)
            .with_screen(ScreenMode::Native)
            .run(&db, &Backend::sim(2))
            .expect("tiny run")
    }

    fn key(digest: u64) -> CacheKey {
        CacheKey::new(digest, 0.05, GlbParams::default(), ScreenMode::Native)
    }

    #[test]
    fn hit_miss_and_lru_eviction() {
        let run = tiny_run();
        let mut c = ResultCache::new(2);
        assert!(c.get(&key(1)).is_none());
        c.insert(key(1), &run);
        c.insert(key(2), &run);
        // Touch 1 so 2 becomes the LRU victim.
        assert!(c.get(&key(1)).is_some());
        c.insert(key(3), &run);
        assert_eq!(c.len(), 2);
        assert!(c.get(&key(2)).is_none(), "LRU entry must have been evicted");
        assert!(c.get(&key(1)).is_some());
        assert!(c.get(&key(3)).is_some());
        let (hits, misses) = c.stats();
        assert_eq!((hits, misses), (4, 2));
        // Re-inserting an existing key refreshes, never grows.
        c.insert(key(1), &run);
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn cached_outcome_is_prebuilt_and_shared() {
        let run = tiny_run();
        let mut c = ResultCache::new(2);
        c.insert(key(1), &run);
        let outcome = c.get(&key(1)).expect("hit");
        assert!(outcome.from_cache, "cached outcome must be pre-marked");
        assert_eq!(outcome.lambda_final, run.result.lambda_final);
        assert_eq!(outcome.correction_factor, run.result.correction_factor);
        // A second hit hands out the same allocation, not a deep copy.
        let again = c.get(&key(1)).expect("hit");
        assert!(Arc::ptr_eq(&outcome, &again));
    }

    #[test]
    fn key_separates_every_component() {
        let base = key(1);
        assert_ne!(base, key(2));
        assert_ne!(base, CacheKey::new(1, 0.01, GlbParams::default(), ScreenMode::Native));
        assert_ne!(base, CacheKey::new(1, 0.05, GlbParams::naive(), ScreenMode::Native));
        assert_ne!(base, CacheKey::new(1, 0.05, GlbParams::default(), ScreenMode::Auto));
    }
}
