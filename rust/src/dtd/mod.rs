//! Distributed termination detection (paper §4.3).
//!
//! Mattern's *time algorithm* with bounded clocks, adapted from the
//! original star topology to a spanning tree — the paper uses a **ternary**
//! tree, as do we ([`tree::SpanningTree`]). Control waves sweep down and
//! up the tree; each process reports its cumulative basic-message deficit
//! (`sends − receives`) plus a cut-consistency flag derived from message
//! time-stamps, and the root declares termination only from a consistent
//! zero-deficit, all-idle wave.
//!
//! The closed-itemset histogram gather and λ broadcast (paper §4.4) are
//! piggybacked on the same waves: `WaveUp` carries each subtree's
//! histogram delta, `WaveDown` carries the freshest global λ. Staleness
//! only costs wasted work, never correctness.

pub mod mattern;
pub mod tree;

pub use mattern::{DtdNode, WaveOutcome};
pub use tree::SpanningTree;
