//! Packed bitmap algebra.
//!
//! The paper (§4.6) targets dense databases with relatively few
//! transactions and deliberately *excludes* database-reduction techniques,
//! counting supports with the population-count instruction over packed
//! occurrence bitmaps instead. [`BitVec`] is that representation: one bit
//! per transaction, `u64` words, with the AND / ANDNOT / popcount kernels
//! the LCM expansion loop is built from.

mod bitvec;

pub use bitvec::BitVec;

/// Number of `u64` words needed for `nbits` bits.
#[inline]
pub const fn words_for(nbits: usize) -> usize {
    nbits.div_ceil(64)
}

/// Popcount of the intersection of two word slices — the innermost support
/// counting kernel. Slices must be the same length.
///
/// Kept as a free function so benches can target it directly; unrolled by
/// fours which measurably helps on the dense workloads the paper targets
/// (see EXPERIMENTS.md §Perf).
#[inline]
pub fn and_popcount(a: &[u64], b: &[u64]) -> u32 {
    // Hard assert: with the zipped loops below a length mismatch would
    // silently truncate (wrong supports), not panic like indexing did.
    assert_eq!(a.len(), b.len());
    let mut acc0: u32 = 0;
    let mut acc1: u32 = 0;
    let mut acc2: u32 = 0;
    let mut acc3: u32 = 0;
    let mut ca = a.chunks_exact(4);
    let mut cb = b.chunks_exact(4);
    for (x, y) in (&mut ca).zip(&mut cb) {
        acc0 += (x[0] & y[0]).count_ones();
        acc1 += (x[1] & y[1]).count_ones();
        acc2 += (x[2] & y[2]).count_ones();
        acc3 += (x[3] & y[3]).count_ones();
    }
    for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
        acc0 += (x & y).count_ones();
    }
    acc0 + acc1 + acc2 + acc3
}

/// `true` iff `a & b == a` (i.e. `a ⊆ b`), early-exiting on the first
/// violating word. Used by the closure computation.
#[inline]
pub fn subset_of(a: &[u64], b: &[u64]) -> bool {
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(b) {
        if x & !y != 0 {
            return false;
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::forall;
    use crate::util::rng::Rng;

    fn random_words(rng: &mut Rng, n: usize) -> Vec<u64> {
        (0..n).map(|_| rng.next_u64()).collect()
    }

    #[test]
    fn words_for_boundaries() {
        assert_eq!(words_for(0), 0);
        assert_eq!(words_for(1), 1);
        assert_eq!(words_for(64), 1);
        assert_eq!(words_for(65), 2);
        assert_eq!(words_for(697), 11); // HapMap transaction count
    }

    #[test]
    fn and_popcount_matches_naive() {
        forall("and_popcount == naive", 128, |rng| {
            let n = rng.index(9); // cover remainder paths 0..8 words
            let a = random_words(rng, n);
            let b = random_words(rng, n);
            let naive: u32 = a.iter().zip(&b).map(|(x, y)| (x & y).count_ones()).sum();
            if and_popcount(&a, &b) != naive {
                return Err(format!("n={n}"));
            }
            Ok(())
        });
    }

    #[test]
    fn subset_of_matches_definition() {
        forall("subset_of == definition", 128, |rng| {
            let n = 1 + rng.index(6);
            let b = random_words(rng, n);
            // generate a ⊆ b half the time, random otherwise
            let a: Vec<u64> = if rng.bernoulli(0.5) {
                b.iter().map(|w| w & rng.next_u64()).collect()
            } else {
                random_words(rng, n)
            };
            let naive = a.iter().zip(&b).all(|(x, y)| x & y == *x);
            if subset_of(&a, &b) != naive {
                return Err(format!("a={a:?} b={b:?}"));
            }
            Ok(())
        });
    }
}
