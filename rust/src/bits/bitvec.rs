//! Fixed-width packed bit vector.

use super::{and_popcount, subset_of, words_for};

/// A fixed-length bit vector packed into `u64` words, little-endian within
/// each word (bit `i` lives at word `i / 64`, bit `i % 64`).
///
/// Represents the *occurrence bitmap* of an itemset: bit `t` is set iff
/// transaction `t` contains the itemset. Trailing bits past `len` are kept
/// zero as an invariant so popcounts never over-count.
///
/// # Examples
///
/// The miner's hot path is AND + popcount over occurrence bitmaps (paper
/// §4.6): intersecting two itemsets' occurrences gives the support of
/// their union, without materializing the intersection.
///
/// ```
/// use parlamp::bits::BitVec;
///
/// let a = BitVec::from_indices(100, [0, 3, 64, 99]); // transactions with itemset A
/// let b = BitVec::from_indices(100, [3, 64, 65]);    // transactions with itemset B
/// assert_eq!(a.count(), 4);
/// assert_eq!(a.and_count(&b), 2);                    // support of A ∪ B
/// assert_eq!(a.and(&b).iter_ones().collect::<Vec<_>>(), vec![3, 64]);
/// assert!(a.and(&b).is_subset_of(&a));
/// ```
#[derive(Clone, PartialEq, Eq, Hash)]
pub struct BitVec {
    len: usize,
    words: Vec<u64>,
}

impl std::fmt::Debug for BitVec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "BitVec[{}; ", self.len)?;
        for i in 0..self.len.min(128) {
            write!(f, "{}", u8::from(self.get(i)))?;
        }
        if self.len > 128 {
            write!(f, "…")?;
        }
        write!(f, "]")
    }
}

impl BitVec {
    /// All-zero vector of `len` bits.
    pub fn zeros(len: usize) -> Self {
        BitVec { len, words: vec![0; words_for(len)] }
    }

    /// All-one vector of `len` bits (trailing bits zeroed).
    pub fn ones(len: usize) -> Self {
        let mut v = BitVec { len, words: vec![!0u64; words_for(len)] };
        v.mask_tail();
        v
    }

    /// Build from an iterator of set bit positions.
    pub fn from_indices(len: usize, idx: impl IntoIterator<Item = usize>) -> Self {
        let mut v = Self::zeros(len);
        for i in idx {
            v.set(i, true);
        }
        v
    }

    /// Zero any bits past `len` in the last word (representation invariant).
    fn mask_tail(&mut self) {
        let rem = self.len % 64;
        if rem != 0 {
            if let Some(last) = self.words.last_mut() {
                *last &= (1u64 << rem) - 1;
            }
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        self.words[i / 64] >> (i % 64) & 1 == 1
    }

    #[inline]
    pub fn set(&mut self, i: usize, v: bool) {
        debug_assert!(i < self.len);
        let w = &mut self.words[i / 64];
        if v {
            *w |= 1u64 << (i % 64);
        } else {
            *w &= !(1u64 << (i % 64));
        }
    }

    /// Number of set bits — the *support* when this is an occurrence bitmap.
    #[inline]
    pub fn count(&self) -> u32 {
        self.words.iter().map(|w| w.count_ones()).sum()
    }

    /// Popcount of `self & other` without materializing the intersection.
    #[inline]
    pub fn and_count(&self, other: &BitVec) -> u32 {
        debug_assert_eq!(self.len, other.len);
        and_popcount(&self.words, &other.words)
    }

    /// `self ∧ other` into a fresh vector.
    pub fn and(&self, other: &BitVec) -> BitVec {
        debug_assert_eq!(self.len, other.len);
        let words = self.words.iter().zip(&other.words).map(|(a, b)| a & b).collect();
        BitVec { len: self.len, words }
    }

    /// `self & other` into `out`, reusing `out`'s allocation (hot path:
    /// child occurrence bitmaps in the innermost expansion loop).
    ///
    /// When `out` already holds a buffer of the right width — the steady
    /// state, since the expansion loop recycles one scratch vector per
    /// depth — the words are bulk-copied with `copy_from_slice` (memcpy)
    /// and AND-ed in place, instead of the clear-then-extend path whose
    /// per-element `push` the optimizer must see through. The first use
    /// of a scratch buffer (or a width change) falls back to
    /// clear+extend, which also (re)sizes the allocation.
    #[inline]
    pub fn and_assign_into(&self, other: &BitVec, out: &mut BitVec) {
        debug_assert_eq!(self.len, other.len);
        out.len = self.len;
        if out.words.len() == self.words.len() {
            out.words.copy_from_slice(&self.words);
            for (o, b) in out.words.iter_mut().zip(&other.words) {
                *o &= b;
            }
        } else {
            out.words.clear();
            out.words.extend(self.words.iter().zip(&other.words).map(|(a, b)| a & b));
        }
    }

    /// `true` iff every set bit of `self` is also set in `other`.
    #[inline]
    pub fn is_subset_of(&self, other: &BitVec) -> bool {
        debug_assert_eq!(self.len, other.len);
        subset_of(&self.words, &other.words)
    }

    /// Iterate over the indices of set bits in ascending order.
    ///
    /// ```
    /// use parlamp::bits::BitVec;
    ///
    /// let v = BitVec::from_indices(130, [1, 64, 129]);
    /// assert_eq!(v.iter_ones().collect::<Vec<_>>(), vec![1, 64, 129]);
    /// ```
    pub fn iter_ones(&self) -> impl Iterator<Item = usize> + '_ {
        self.words.iter().enumerate().flat_map(|(wi, &w)| {
            let mut w = w;
            std::iter::from_fn(move || {
                if w == 0 {
                    None
                } else {
                    let b = w.trailing_zeros() as usize;
                    w &= w - 1;
                    Some(wi * 64 + b)
                }
            })
        })
    }

    /// Pack into little-endian `u32` words (the layout the XLA screen
    /// artifact consumes — see `runtime::screen`).
    pub fn to_u32_words(&self, out_words: usize) -> Vec<u32> {
        let mut out = Vec::with_capacity(out_words);
        for w in &self.words {
            out.push(*w as u32);
            out.push((*w >> 32) as u32);
        }
        out.resize(out_words, 0);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::forall;

    #[test]
    fn zeros_and_ones() {
        let z = BitVec::zeros(70);
        assert_eq!(z.count(), 0);
        let o = BitVec::ones(70);
        assert_eq!(o.count(), 70);
        assert_eq!(o.words().len(), 2);
        // tail must be masked
        assert_eq!(o.words()[1] >> 6, 0);
    }

    #[test]
    fn set_get_roundtrip() {
        let mut v = BitVec::zeros(130);
        v.set(0, true);
        v.set(64, true);
        v.set(129, true);
        assert!(v.get(0) && v.get(64) && v.get(129));
        assert!(!v.get(1) && !v.get(128));
        assert_eq!(v.count(), 3);
        v.set(64, false);
        assert_eq!(v.count(), 2);
    }

    #[test]
    fn from_indices_and_iter_ones_roundtrip() {
        forall("iter_ones(from_indices(s)) == s", 64, |rng| {
            let len = 1 + rng.index(300);
            let mut idx: Vec<usize> = (0..len).filter(|_| rng.bernoulli(0.3)).collect();
            let v = BitVec::from_indices(len, idx.iter().copied());
            idx.sort_unstable();
            idx.dedup();
            let got: Vec<usize> = v.iter_ones().collect();
            if got != idx {
                return Err(format!("len={len} got={got:?} want={idx:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn and_count_equals_and_then_count() {
        forall("and_count == and().count()", 64, |rng| {
            let len = 1 + rng.index(200);
            let a = BitVec::from_indices(len, (0..len).filter(|_| rng.bernoulli(0.5)));
            let b = BitVec::from_indices(len, (0..len).filter(|_| rng.bernoulli(0.5)));
            if a.and_count(&b) != a.and(&b).count() {
                return Err(format!("len={len}"));
            }
            Ok(())
        });
    }

    #[test]
    fn subset_reflexive_and_antisymmetric_on_count() {
        forall("subset properties", 64, |rng| {
            let len = 1 + rng.index(150);
            let a = BitVec::from_indices(len, (0..len).filter(|_| rng.bernoulli(0.4)));
            let b = a.and(&BitVec::from_indices(len, (0..len).filter(|_| rng.bernoulli(0.7))));
            if !a.is_subset_of(&a) {
                return Err("not reflexive".into());
            }
            if !b.is_subset_of(&a) {
                return Err("b = a∧x must be ⊆ a".into());
            }
            if b.is_subset_of(&a) && a.is_subset_of(&b) && a != b {
                return Err("mutual subset but unequal".into());
            }
            Ok(())
        });
    }

    #[test]
    fn to_u32_words_layout() {
        let mut v = BitVec::zeros(96);
        v.set(0, true);
        v.set(33, true);
        v.set(65, true);
        let w = v.to_u32_words(4);
        assert_eq!(w, vec![1, 2, 2, 0]);
        // pads with zeros
        assert_eq!(v.to_u32_words(6).len(), 6);
    }

    #[test]
    fn and_assign_into_reuses_buffer() {
        let a = BitVec::ones(100);
        let b = BitVec::from_indices(100, [3, 50, 99]);
        let mut out = BitVec::zeros(100);
        a.and_assign_into(&b, &mut out);
        assert_eq!(out.iter_ones().collect::<Vec<_>>(), vec![3, 50, 99]);
    }

    /// Both `and_assign_into` paths — the right-width memcpy fast path and
    /// the resize fallback — must equal the fresh `and()` result.
    #[test]
    fn and_assign_into_paths_match_and() {
        forall("and_assign_into == and()", 64, |rng| {
            let len = 1 + rng.index(400);
            let a = BitVec::from_indices(len, (0..len).filter(|_| rng.bernoulli(0.4)));
            let b = BitVec::from_indices(len, (0..len).filter(|_| rng.bernoulli(0.4)));
            let want = a.and(&b);
            // resize path: out starts with a different word width
            let mut out = BitVec::zeros(rng.index(2 * len) + 1);
            a.and_assign_into(&b, &mut out);
            if out != want {
                return Err(format!("resize path differs at len={len}"));
            }
            // fast path: out already has the right width (and stale bits)
            let mut out = BitVec::ones(len);
            a.and_assign_into(&b, &mut out);
            if out != want {
                return Err(format!("fast path differs at len={len}"));
            }
            Ok(())
        });
    }
}
