//! Artifact manifest parsing.
//!
//! `manifest.json` freezes the shapes the screen artifact was lowered
//! with. The offline build has no serde, and the manifest is flat, so a
//! small key scanner suffices (validated against malformed input in
//! tests).

use std::path::Path;

use anyhow::{bail, Context, Result};

/// Frozen artifact shapes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Manifest {
    /// Batch capacity (candidate rows per execution).
    pub k: usize,
    /// `u32` words per packed bitmap (supports up to `32·w` transactions).
    pub w: usize,
    /// Fisher tail capacity; requires `n_pos + 1 ≤ t_max`.
    pub t_max: usize,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {}", path.display()))?;
        Self::parse(&text)
    }

    /// Extract the three top-level integer fields.
    pub fn parse(text: &str) -> Result<Manifest> {
        let k = scan_usize(text, "\"k\"")?;
        let w = scan_usize(text, "\"w\"")?;
        let t_max = scan_usize(text, "\"t_max\"")?;
        if k == 0 || w == 0 || t_max == 0 {
            bail!("manifest has zero-sized shapes: k={k} w={w} t_max={t_max}");
        }
        Ok(Manifest { k, w, t_max })
    }

    /// Max transactions a bitmap row can hold.
    pub fn max_transactions(&self) -> usize {
        self.w * 32
    }
}

/// Find `"key": <integer>` at the top level (first occurrence).
fn scan_usize(text: &str, key: &str) -> Result<usize> {
    let at = text.find(key).with_context(|| format!("manifest missing {key}"))?;
    let rest = &text[at + key.len()..];
    let colon = rest.find(':').context("missing ':' after key")?;
    let digits: String = rest[colon + 1..]
        .chars()
        .skip_while(|c| c.is_whitespace())
        .take_while(char::is_ascii_digit)
        .collect();
    digits.parse::<usize>().with_context(|| format!("bad integer for {key}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_generated_manifest() {
        let text = r#"{
  "k": 1024,
  "w": 64,
  "t_max": 512,
  "entries": { "screen": { "file": "screen.hlo.txt" } }
}"#;
        let m = Manifest::parse(text).unwrap();
        assert_eq!(m, Manifest { k: 1024, w: 64, t_max: 512 });
        assert_eq!(m.max_transactions(), 2048);
    }

    #[test]
    fn rejects_missing_keys() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse(r#"{"k": 4, "w": 2}"#).is_err());
    }

    #[test]
    fn rejects_zero_shapes() {
        assert!(Manifest::parse(r#"{"k": 0, "w": 2, "t_max": 3}"#).is_err());
    }
}
