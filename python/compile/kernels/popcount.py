"""L1 Pallas kernel: packed-bitmap AND + popcount support counting.

The paper's innermost operation (§4.6): supports are counted with the
population-count instruction over packed occurrence bitmaps instead of
database reduction. On TPU-shaped hardware this maps to a VPU SWAR
popcount over BlockSpec-tiled slabs: the candidate axis rides the grid,
each (BK, W) uint32 slab is staged HBM→VMEM once, and the W-axis reduction
stays in registers. (DESIGN.md §5 Hardware-Adaptation; popcount is not an
MXU op — there is no matmul to chase here.)

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; the interpret path lowers to plain HLO, which is exactly
what the rust runtime loads.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Candidate-axis block size. W (words per bitmap) is never tiled: real
# transaction counts (hundreds to ~13k bits = tens to ~400 words) keep a
# (BK, W) uint32 slab comfortably under VMEM (BK=256, W=512 → 512 KiB).
BLOCK_K = 256


def _popcount_u32(v):
    """SWAR popcount; identical arithmetic to ref.popcount_u32 but kept
    local so the kernel is self-contained under tracing."""
    v = v - ((v >> 1) & jnp.uint32(0x55555555))
    v = (v & jnp.uint32(0x33333333)) + ((v >> 2) & jnp.uint32(0x33333333))
    v = (v + (v >> 4)) & jnp.uint32(0x0F0F0F0F)
    return ((v * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)


def _support_kernel(occ_ref, pos_ref, x_ref, n_ref):
    """One (BK, W) tile: x = popcount(occ), n = popcount(occ & pos)."""
    occ = occ_ref[...]
    pos = pos_ref[...]
    x_ref[...] = _popcount_u32(occ).sum(axis=1, dtype=jnp.int32)
    n_ref[...] = _popcount_u32(occ & pos[None, :]).sum(axis=1, dtype=jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_k",))
def support_counts(occ_words, pos_words, *, block_k=BLOCK_K):
    """Supports of K packed candidate bitmaps.

    occ_words: (K, W) uint32, K divisible by block_k (callers pad).
    pos_words: (W,) uint32.
    Returns (x, n): (K,) int32 each.
    """
    k, w = occ_words.shape
    assert k % block_k == 0, f"K={k} must be padded to a multiple of {block_k}"
    grid = (k // block_k,)
    return pl.pallas_call(
        _support_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_k, w), lambda i: (i, 0)),
            pl.BlockSpec((w,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_k,), lambda i: (i,)),
            pl.BlockSpec((block_k,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k,), jnp.int32),
            jax.ShapeDtypeStruct((k,), jnp.int32),
        ],
        interpret=True,
    )(occ_words, pos_words)
