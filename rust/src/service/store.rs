//! Disk-backed persistent result store (DESIGN.md §13).
//!
//! The in-memory LRU ([`super::cache`]) dies with the daemon; this store
//! does not. Every mined result is appended to a single log file, and on
//! startup the log is scanned back into an index so a restarted daemon
//! answers repeat submissions from disk without running a single fleet
//! phase.
//!
//! ## Record format
//!
//! ```text
//! file    := magic:"PLAMPST1"  record*
//! record  := body_len:u32  body  fnv64(body):u64
//! body    := key  outcome
//! key     := digest:u64 alpha_bits:u64 l:u32 w:u32 steal:u8 pre:u8
//!            arity:u32 screen:u8                      (31 bytes)
//! outcome := the RESULT frame's JobOutcome payload, byte-for-byte
//!            (wire::service::encode_job_outcome)
//! ```
//!
//! Integers are little-endian, like the wire format the `outcome` bytes
//! already use. The checksum is FNV-1a over the whole body — each FNV
//! step is a bijection on the 64-bit state (the prime is odd), so any
//! single-byte flip in the body is *guaranteed* to change the checksum.
//!
//! ## Recovery rules
//!
//! The scan accepts records strictly left to right. The first record that
//! is truncated (fewer bytes than its header promises), length-corrupt
//! (absurd `body_len`), checksum-corrupt, or undecodable ends the scan:
//! everything before it is intact and loads; everything from it on is
//! dropped by truncating the file back to the last good boundary, so the
//! store stays appendable at a clean record edge. One line is logged when
//! a tail is dropped. A duplicate key keeps the *latest* record (the log
//! is append-only; re-mining a key after an eviction appends a fresh
//! record rather than rewriting history).
//!
//! Reads go through [`FileExt::read_at`] and take `&self`, so concurrent
//! lookups proceed under a shared lock while appends (`&mut self`)
//! serialize — the read-while-append test below exercises exactly that.

use std::collections::HashMap;
use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::os::unix::fs::FileExt;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};

use crate::coordinator::ScreenMode;
use crate::wire::service::{decode_job_outcome, encode_job_outcome, JobOutcome};
use crate::wire::MAX_FRAME_LEN;

use super::CacheKey;

/// First eight bytes of every store file ("ParLamp STore v1").
const STORE_MAGIC: [u8; 8] = *b"PLAMPST1";

/// Encoded [`CacheKey`] size inside a record body.
const KEY_BYTES: usize = 31;

/// `body_len:u32` header + trailing `fnv64:u64` checksum.
const RECORD_OVERHEAD: usize = 4 + 8;

fn fnv64(bytes: &[u8]) -> u64 {
    // Same constants as `Database::digest` (FNV-1a).
    const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = FNV_OFFSET;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

fn put_key(buf: &mut Vec<u8>, key: &CacheKey) {
    buf.extend_from_slice(&key.digest.to_le_bytes());
    buf.extend_from_slice(&key.alpha_bits.to_le_bytes());
    buf.extend_from_slice(&(key.glb.l as u32).to_le_bytes());
    buf.extend_from_slice(&(key.glb.w as u32).to_le_bytes());
    buf.push(key.glb.steal as u8);
    buf.push(key.glb.preprocess as u8);
    buf.extend_from_slice(&(key.glb.tree_arity as u32).to_le_bytes());
    buf.push(match key.screen {
        ScreenMode::Auto => 0,
        ScreenMode::Native => 1,
        ScreenMode::Xla => 2,
    });
}

fn get_key(bytes: &[u8]) -> Result<CacheKey> {
    ensure!(bytes.len() >= KEY_BYTES, "store: record body shorter than its key");
    let u64_at = |o: usize| u64::from_le_bytes(bytes[o..o + 8].try_into().unwrap());
    let u32_at = |o: usize| u32::from_le_bytes(bytes[o..o + 4].try_into().unwrap());
    Ok(CacheKey {
        digest: u64_at(0),
        alpha_bits: u64_at(8),
        glb: crate::coordinator::GlbParams {
            l: u32_at(16) as usize,
            w: u32_at(20) as usize,
            steal: bytes[24] != 0,
            preprocess: bytes[25] != 0,
            tree_arity: u32_at(26) as usize,
        },
        screen: match bytes[30] {
            0 => ScreenMode::Auto,
            1 => ScreenMode::Native,
            2 => ScreenMode::Xla,
            other => bail!("store: unknown screen byte {other:#x}"),
        },
    })
}

/// The append-only, checksummed, indexed result log.
#[derive(Debug)]
pub struct ResultStore {
    path: PathBuf,
    file: File,
    /// Key → (absolute body offset, body length). Latest record wins.
    index: HashMap<CacheKey, (u64, u32)>,
    /// Keys from oldest to newest append (deduplicated), for warm-load
    /// recency.
    order: Vec<CacheKey>,
    /// End of the last intact record — where the next append goes.
    end: u64,
    appends: u64,
}

impl ResultStore {
    /// Open (or create) the store at `path`, scanning every intact record
    /// into the index and truncating a corrupt or torn tail per the
    /// recovery rules above.
    pub fn open(path: &Path) -> Result<ResultStore> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .with_context(|| format!("store: creating {}", parent.display()))?;
            }
        }
        let mut file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .open(path)
            .with_context(|| format!("store: opening {}", path.display()))?;
        let mut bytes = Vec::new();
        file.read_to_end(&mut bytes)
            .with_context(|| format!("store: reading {}", path.display()))?;
        if bytes.is_empty() {
            file.write_all(&STORE_MAGIC)
                .with_context(|| format!("store: initializing {}", path.display()))?;
            bytes.extend_from_slice(&STORE_MAGIC);
        }
        // Never silently treat a foreign file as an empty store.
        ensure!(
            bytes.len() >= STORE_MAGIC.len() && bytes[..STORE_MAGIC.len()] == STORE_MAGIC,
            "store: {} is not a parlamp result store (bad magic)",
            path.display()
        );
        let mut store = ResultStore {
            path: path.to_path_buf(),
            file,
            index: HashMap::new(),
            order: Vec::new(),
            end: STORE_MAGIC.len() as u64,
            appends: 0,
        };
        store.scan(&bytes)?;
        Ok(store)
    }

    /// Walk records from `end`, stopping at the first truncated or corrupt
    /// one and truncating the file back to the last good boundary.
    fn scan(&mut self, bytes: &[u8]) -> Result<()> {
        let mut pos = self.end as usize;
        loop {
            let Some(reason) = self.try_record(bytes, &mut pos) else { continue };
            if reason.is_empty() {
                break; // clean end of log
            }
            let dropped = bytes.len() as u64 - self.end;
            crate::obs::log::warn(
                "store",
                &crate::obs::log::Tags::NONE,
                format_args!(
                    "{}: dropped {dropped}-byte tail at offset {} ({reason})",
                    self.path.display(),
                    self.end
                ),
            );
            self.file
                .set_len(self.end)
                .with_context(|| format!("store: truncating {}", self.path.display()))?;
            break;
        }
        Ok(())
    }

    /// Try to accept one record at `*pos`. `None` = accepted (index
    /// updated, `pos` and `end` advanced). `Some("")` = clean EOF.
    /// `Some(reason)` = corrupt/torn tail starting here.
    fn try_record(&mut self, bytes: &[u8], pos: &mut usize) -> Option<&'static str> {
        if *pos == bytes.len() {
            return Some("");
        }
        if bytes.len() - *pos < 4 {
            return Some("torn length prefix");
        }
        let body_len =
            u32::from_le_bytes(bytes[*pos..*pos + 4].try_into().unwrap()) as usize;
        if body_len < KEY_BYTES || body_len > MAX_FRAME_LEN as usize {
            return Some("absurd record length");
        }
        if bytes.len() - *pos - 4 < body_len + 8 {
            return Some("torn record");
        }
        let body = &bytes[*pos + 4..*pos + 4 + body_len];
        let sum_off = *pos + 4 + body_len;
        let sum = u64::from_le_bytes(bytes[sum_off..sum_off + 8].try_into().unwrap());
        if fnv64(body) != sum {
            return Some("checksum mismatch");
        }
        let Ok(key) = get_key(body) else {
            return Some("undecodable key");
        };
        if decode_job_outcome(&body[KEY_BYTES..]).is_err() {
            return Some("undecodable outcome");
        }
        let body_off = (*pos + 4) as u64;
        if self.index.insert(key, (body_off, body_len as u32)).is_some() {
            self.order.retain(|k| k != &key);
        }
        self.order.push(key);
        *pos += 4 + body_len + 8;
        self.end = *pos as u64;
        None
    }

    /// Append one result. The record is checksummed and synced; on return
    /// it will survive a daemon restart.
    pub fn append(&mut self, key: CacheKey, outcome: &JobOutcome) -> Result<()> {
        let mut body = Vec::new();
        put_key(&mut body, &key);
        body.extend_from_slice(&encode_job_outcome(outcome));
        let mut record = Vec::with_capacity(body.len() + RECORD_OVERHEAD);
        record.extend_from_slice(&(body.len() as u32).to_le_bytes());
        record.extend_from_slice(&body);
        record.extend_from_slice(&fnv64(&body).to_le_bytes());
        self.file
            .seek(SeekFrom::Start(self.end))
            .and_then(|_| self.file.write_all(&record))
            .and_then(|_| self.file.sync_data())
            .with_context(|| format!("store: appending to {}", self.path.display()))?;
        let body_off = self.end + 4;
        if self.index.insert(key, (body_off, body.len() as u32)).is_some() {
            self.order.retain(|k| k != &key);
        }
        self.order.push(key);
        self.end += record.len() as u64;
        self.appends += 1;
        Ok(())
    }

    /// Look up a stored result. Takes `&self` (positional `read_at`), so
    /// lookups run concurrently under a shared lock while appends hold the
    /// exclusive one. The checksum is re-verified on every read.
    pub fn get(&self, key: &CacheKey) -> Option<Arc<JobOutcome>> {
        let &(off, len) = self.index.get(key)?;
        let mut body = vec![0u8; len as usize + 8];
        self.file.read_exact_at(&mut body, off).ok()?;
        let sum = u64::from_le_bytes(body[len as usize..].try_into().unwrap());
        let body = &body[..len as usize];
        if fnv64(body) != sum {
            return None;
        }
        let mut outcome = decode_job_outcome(&body[KEY_BYTES..]).ok()?;
        // Anything answered from the store is by definition a cache hit.
        outcome.from_cache = true;
        Some(Arc::new(outcome))
    }

    /// The most recent `cap` entries, oldest first — feed them to
    /// [`super::ResultCache::insert_outcome`] in order and the newest ends
    /// up most-recently-used.
    pub fn recent(&self, cap: usize) -> Vec<(CacheKey, Arc<JobOutcome>)> {
        let skip = self.order.len().saturating_sub(cap);
        self.order[skip..]
            .iter()
            .filter_map(|k| self.get(k).map(|o| (*k, o)))
            .collect()
    }

    /// Number of distinct keys on disk.
    pub fn len(&self) -> usize {
        self.index.len()
    }

    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }

    /// Appends performed by *this* process (not counting records loaded
    /// from a previous run).
    pub fn appends(&self) -> u64 {
        self.appends
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Backend, Coordinator, CoordinatorRun, GlbParams};
    use crate::datagen::{generate_gwas, GwasSpec};
    use std::sync::RwLock;

    fn tiny_run() -> CoordinatorRun {
        let spec = GwasSpec { n_snps: 40, n_individuals: 30, n_pos: 8, ..GwasSpec::small(3) };
        let (db, _) = generate_gwas(&spec);
        Coordinator::new(0.05)
            .with_screen(ScreenMode::Native)
            .run(&db, &Backend::sim(2))
            .expect("tiny run")
    }

    fn key(digest: u64) -> CacheKey {
        CacheKey::new(digest, 0.05, GlbParams::default(), ScreenMode::Native)
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "parlamp-store-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    /// A store at `path` holding `k` records under keys `0..k`.
    fn seeded(path: &Path, k: u64) -> JobOutcome {
        let outcome = JobOutcome::from_run(&tiny_run(), true);
        let mut store = ResultStore::open(path).unwrap();
        for digest in 0..k {
            store.append(key(digest), &outcome).unwrap();
        }
        assert_eq!(store.appends(), k);
        outcome
    }

    #[test]
    fn roundtrips_across_reopen() {
        let dir = tmpdir("roundtrip");
        let path = dir.join("results.log");
        let outcome = seeded(&path, 3);
        let store = ResultStore::open(&path).unwrap();
        assert_eq!(store.len(), 3);
        for digest in 0..3 {
            let got = store.get(&key(digest)).expect("stored record");
            assert_eq!(*got, outcome);
        }
        assert!(store.get(&key(99)).is_none());
        // Warm-load order: most recent last, capped.
        let recent = store.recent(2);
        assert_eq!(recent.len(), 2);
        assert_eq!(recent[0].0, key(1));
        assert_eq!(recent[1].0, key(2));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn duplicate_key_keeps_latest_record() {
        let dir = tmpdir("dup");
        let path = dir.join("results.log");
        let run = tiny_run();
        let first = JobOutcome::from_run(&run, true);
        let mut second = first.clone();
        second.phase2_closed += 1;
        {
            let mut store = ResultStore::open(&path).unwrap();
            store.append(key(7), &first).unwrap();
            store.append(key(7), &second).unwrap();
            assert_eq!(store.len(), 1);
            assert_eq!(store.get(&key(7)).unwrap().phase2_closed, second.phase2_closed);
        }
        let store = ResultStore::open(&path).unwrap();
        assert_eq!(store.len(), 1);
        assert_eq!(store.get(&key(7)).unwrap().phase2_closed, second.phase2_closed);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn foreign_file_is_rejected_not_clobbered() {
        let dir = tmpdir("foreign");
        let path = dir.join("notastore");
        std::fs::write(&path, b"definitely not a store").unwrap();
        let err = ResultStore::open(&path).unwrap_err();
        assert!(format!("{err:#}").contains("bad magic"), "{err:#}");
        assert_eq!(std::fs::read(&path).unwrap(), b"definitely not a store");
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// The crash-recovery battery: truncate the log at *every* byte offset
    /// of the last record; every prefix must reopen with all intact
    /// records, drop the torn tail, and stay appendable.
    #[test]
    fn truncated_tail_at_every_offset_recovers() {
        const K: u64 = 3;
        let dir = tmpdir("trunc");
        let path = dir.join("results.log");
        let outcome = seeded(&path, K);
        let full = std::fs::read(&path).unwrap();
        // Last record start = end of the store holding K-1 records.
        let last_start = {
            let prefix = dir.join("prefix.log");
            seeded(&prefix, K - 1);
            std::fs::metadata(&prefix).unwrap().len() as usize
        };
        assert!(last_start < full.len());
        let scratch = dir.join("scratch.log");
        for cut in last_start..full.len() {
            std::fs::write(&scratch, &full[..cut]).unwrap();
            let mut store = ResultStore::open(&scratch).unwrap();
            assert_eq!(store.len() as u64, K - 1, "cut at {cut}");
            for digest in 0..K - 1 {
                assert_eq!(*store.get(&key(digest)).unwrap(), outcome, "cut at {cut}");
            }
            // The truncated tail is gone and the store accepts appends at
            // the recovered boundary.
            store.append(key(1000 + cut as u64), &outcome).unwrap();
            drop(store);
            let reopened = ResultStore::open(&scratch).unwrap();
            assert_eq!(reopened.len() as u64, K, "cut at {cut}");
            assert_eq!(*reopened.get(&key(1000 + cut as u64)).unwrap(), outcome);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Corrupt (flip) every byte of the last record in place: the store
    /// must reopen with the intact records only — the checksum (or, for
    /// length-field flips, the torn-tail rule) eats the damage.
    #[test]
    fn corrupt_tail_at_every_offset_recovers() {
        const K: u64 = 3;
        let dir = tmpdir("corrupt");
        let path = dir.join("results.log");
        let outcome = seeded(&path, K);
        let full = std::fs::read(&path).unwrap();
        let last_start = {
            let prefix = dir.join("prefix.log");
            seeded(&prefix, K - 1);
            std::fs::metadata(&prefix).unwrap().len() as usize
        };
        let scratch = dir.join("scratch.log");
        for flip in last_start..full.len() {
            let mut bytes = full.clone();
            bytes[flip] ^= 0xA5;
            std::fs::write(&scratch, &bytes).unwrap();
            let mut store = ResultStore::open(&scratch).unwrap();
            assert_eq!(store.len() as u64, K - 1, "flip at {flip}");
            for digest in 0..K - 1 {
                assert_eq!(*store.get(&key(digest)).unwrap(), outcome, "flip at {flip}");
            }
            store.append(key(2000 + flip as u64), &outcome).unwrap();
            let reopened = ResultStore::open(&scratch).unwrap();
            assert_eq!(reopened.len() as u64, K, "flip at {flip}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Lookups take `&self` and go through positional reads: readers on
    /// shared locks race an appender holding the exclusive one, and every
    /// read observes a complete, checksum-valid record.
    #[test]
    fn concurrent_reads_while_appending() {
        let dir = tmpdir("concurrent");
        let path = dir.join("results.log");
        let outcome = seeded(&path, 4);
        let store = Arc::new(RwLock::new(ResultStore::open(&path).unwrap()));
        let readers: Vec<_> = (0..4)
            .map(|r| {
                let store = Arc::clone(&store);
                let expect = outcome.clone();
                std::thread::spawn(move || {
                    for i in 0..200 {
                        let got = store
                            .read()
                            .unwrap()
                            .get(&key((r + i) % 4))
                            .expect("seeded record");
                        assert_eq!(*got, expect);
                    }
                })
            })
            .collect();
        let appended = JobOutcome::from_run(&tiny_run(), true);
        for digest in 100..140 {
            store.write().unwrap().append(key(digest), &appended).unwrap();
        }
        for r in readers {
            r.join().unwrap();
        }
        let store = store.read().unwrap();
        assert_eq!(store.len(), 44);
        assert_eq!(*store.get(&key(139)).unwrap(), appended);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
