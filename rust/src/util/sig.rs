//! Minimal Unix signal plumbing (no `libc` dependency — the two symbols
//! used are part of every Unix libc ABI and are declared directly).
//!
//! Two users:
//! - the `parlamp serve` daemon latches SIGTERM/SIGINT into an atomic flag
//!   (the one async-signal-safe thing a handler may do) and drains
//!   gracefully (DESIGN.md §9);
//! - `parlamp __worker` processes *ignore* SIGINT: a terminal Ctrl-C
//!   delivers SIGINT to the whole foreground process group, and workers
//!   that die mid-phase would turn a graceful daemon drain into a failed
//!   job. Workers are supervised — they exit on the fabric socket's EOF
//!   (or `BYE`), so ignoring the terminal's signal never leaks them.

use std::sync::atomic::{AtomicBool, Ordering};

pub const SIGINT: i32 = 2;
pub const SIGKILL: i32 = 9;
pub const SIGTERM: i32 = 15;

/// `SIG_IGN` as the kernel ABI encodes it.
const SIG_IGN: usize = 1;

/// Latched by [`install_terminate_latch`]'s handler.
static TERMINATE: AtomicBool = AtomicBool::new(false);

type Handler = extern "C" fn(i32);

extern "C" {
    /// POSIX `signal(2)`. The handler slot is pointer-sized; passing it as
    /// `usize` lets the same declaration carry both real handlers and the
    /// `SIG_IGN` sentinel.
    fn signal(signum: i32, handler: usize) -> usize;
    /// POSIX `kill(2)`. Used by the serve watchdog to SIGKILL a wedged
    /// fleet's workers by saved pid — `std::process::Child::kill` needs
    /// `&mut Child`, which the watchdog thread cannot borrow while the
    /// runner thread owns the fleet.
    fn kill(pid: i32, sig: i32) -> i32;
}

extern "C" fn latch(_signum: i32) {
    TERMINATE.store(true, Ordering::SeqCst);
}

/// Route SIGTERM and SIGINT into the terminate latch; poll with
/// [`terminate_requested`].
pub fn install_terminate_latch() {
    let h: Handler = latch;
    unsafe {
        signal(SIGTERM, h as *const () as usize);
        signal(SIGINT, h as *const () as usize);
    }
}

/// Whether a latched SIGTERM/SIGINT has been received.
pub fn terminate_requested() -> bool {
    TERMINATE.load(Ordering::SeqCst)
}

/// Ignore SIGINT for this process (worker processes under a supervisor).
pub fn ignore_interrupts() {
    unsafe {
        signal(SIGINT, SIG_IGN);
    }
}

/// Send `sig` to `pid`; best-effort (a pid that already exited is fine —
/// its zombie is reaped by whoever holds the `Child`). Pids ≤ 0 address
/// process groups in `kill(2)` and are refused here.
pub fn kill_pid(pid: u32, sig: i32) {
    if pid == 0 || pid > i32::MAX as u32 {
        return;
    }
    unsafe {
        kill(pid as i32, sig);
    }
}
