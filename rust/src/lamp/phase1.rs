//! Phase 1 — the serial support-increase search (paper §3.3, Fig. 2).
//!
//! One depth-first traversal of the closed-itemset tree that discovers the
//! optimal minimum support: every visited closed set bumps the per-support
//! histogram, the rule raises λ as soon as condition 3.1 is met, and the
//! rising λ prunes the remaining search. The distributed version
//! (`par::worker`) runs the identical rule at the spanning-tree root with
//! a (harmlessly) delayed histogram.

use crate::db::Database;
use crate::lcm::{mine_closed, MineStats, SupportHist, Visit};

use super::rule::SupportIncreaseRule;

/// Outcome of phase 1.
#[derive(Clone, Debug)]
pub struct Phase1Result {
    /// Final value of the running threshold λ at quiescence.
    pub lambda_final: u32,
    /// The optimal minimum support, `λ_final − 1` (≥ 1).
    pub min_sup: u32,
    /// Closed-set histogram accumulated during the (pruned) traversal.
    /// Exact for supports ≥ `lambda_final`; an undercount below (pruned).
    pub hist: SupportHist,
    /// Traversal statistics.
    pub stats: MineStats,
}

/// Run the support-increase search serially.
pub fn phase1_serial(db: &Database, alpha: f64) -> Phase1Result {
    let rule = SupportIncreaseRule::new(db.marginals(), alpha);
    let mut hist = SupportHist::new(db.n_trans());
    let mut lambda: u32 = 1;

    let stats = mine_closed(db, lambda, |node, current_min| {
        debug_assert!(node.support >= current_min);
        hist.record(node.support);
        lambda = rule.advance(lambda, |l| hist.cs_ge(l));
        (Visit::Continue, lambda)
    });

    Phase1Result { lambda_final: lambda, min_sup: lambda.saturating_sub(1).max(1), hist, stats }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::Item;
    use crate::lcm::brute_force_closed;
    use crate::stats::Marginals;
    use crate::util::propcheck::forall;
    use crate::util::rng::Rng;

    fn random_db(rng: &mut Rng, max_items: usize, max_trans: usize) -> Database {
        let m = 3 + rng.index(max_items - 2);
        let n = 4 + rng.index(max_trans - 3);
        let density = 0.25 + rng.f64() * 0.45;
        let trans: Vec<Vec<Item>> = (0..n)
            .map(|_| (0..m as Item).filter(|_| rng.bernoulli(density)).collect())
            .collect();
        let labels: Vec<bool> = (0..n).map(|t| t < n / 3).collect();
        Database::from_transactions(m, &trans, &labels)
    }

    /// Ground-truth λ*: evaluate condition 3.1 on the *full* closed-set
    /// histogram (no pruning) and advance from 1.
    fn lambda_by_definition(db: &Database, alpha: f64) -> u32 {
        let all = brute_force_closed(db, 1);
        let mut hist = SupportHist::new(db.n_trans());
        for (_, s) in &all {
            hist.record(*s);
        }
        let rule = SupportIncreaseRule::new(db.marginals(), alpha);
        rule.advance(1, |l| hist.cs_ge(l))
    }

    #[test]
    fn matches_unpruned_definition_on_random_dbs() {
        forall("phase1 λ == definitional λ", 40, |rng| {
            let db = random_db(rng, 8, 20);
            let alpha = [0.01, 0.05, 0.2][rng.index(3)];
            let got = phase1_serial(&db, alpha);
            let want = lambda_by_definition(&db, alpha);
            if got.lambda_final != want {
                return Err(format!(
                    "m={} n={} alpha={alpha}: got λ={} want λ={}",
                    db.n_items(),
                    db.n_trans(),
                    got.lambda_final,
                    want
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn histogram_exact_at_and_above_final_lambda() {
        forall("hist exact for s ≥ λ_final", 30, |rng| {
            let db = random_db(rng, 8, 18);
            let got = phase1_serial(&db, 0.05);
            let all = brute_force_closed(&db, 1);
            let mut full = SupportHist::new(db.n_trans());
            for (_, s) in &all {
                full.record(*s);
            }
            for l in got.lambda_final..=db.n_trans() as u32 {
                if got.hist.cs_ge(l) != full.cs_ge(l) {
                    return Err(format!(
                        "λ_final={} level {l}: got {} want {}",
                        got.lambda_final,
                        got.hist.cs_ge(l),
                        full.cs_ge(l)
                    ));
                }
            }
            Ok(())
        });
    }

    /// The paper's Fig. 2 walk-through, reconstructed: a database whose
    /// closed-set supports arrive as 6, 5, … and whose marginals make the
    /// λ=1 and λ=2 thresholds immediately exceedable. We verify the
    /// *semantics* — λ rises exactly when CS(λ) crosses α/f(λ−1), the final
    /// λ's threshold is never exceeded, and min_sup = λ_final − 1.
    #[test]
    fn fig2_semantics() {
        let mut rng = Rng::new(2015);
        for _ in 0..20 {
            let db = random_db(&mut rng, 8, 16);
            let alpha = 0.05;
            let r = phase1_serial(&db, alpha);
            let rule = SupportIncreaseRule::new(db.marginals(), alpha);
            // final λ's threshold not exceeded by the (exact-above-λ) hist
            assert!(
                !rule.exceeded(r.lambda_final, r.hist.cs_ge(r.lambda_final)),
                "CS(λ_final) must not exceed its threshold"
            );
            // every level below was exceeded at some point ⇒ with the full
            // histogram the definitional λ agrees (checked above); here we
            // check min_sup bookkeeping.
            assert_eq!(r.min_sup, r.lambda_final.saturating_sub(1).max(1));
        }
    }

    #[test]
    fn tight_alpha_raises_lambda_higher() {
        let mut rng = Rng::new(7);
        let db = random_db(&mut rng, 8, 20);
        let loose = phase1_serial(&db, 0.2);
        let tight = phase1_serial(&db, 0.001);
        // Smaller α ⇒ smaller thresholds… but thresholds scale with α, so a
        // *smaller* α is exceeded sooner ⇒ λ rises at least as high.
        assert!(tight.lambda_final >= loose.lambda_final);
    }

    #[test]
    fn marginals_sanity() {
        let mut rng = Rng::new(11);
        let db = random_db(&mut rng, 6, 12);
        let Marginals { n, n_pos } = db.marginals();
        assert!(n_pos <= n);
    }
}
