//! The serving layer (DESIGN.md §9): `parlamp` as a long-running mining
//! service instead of a one-shot batch run.
//!
//! Every earlier entry point pays the full startup bill per request —
//! spawn a worker fleet, handshake, ship the database, mine, tear down.
//! The paper's own deployment story is the opposite: a *persistent* set of
//! cores fed work continuously (§4), and the task-parallel literature
//! (PAPERS.md) identifies repeated runtime re-initialization as a dominant
//! cost when mining requests arrive as a stream. This module is where that
//! lives:
//!
//! - [`server::serve`] — the daemon: binds a stream socket (`unix:` or
//!   `tcp:`, DESIGN.md §11), spawns
//!   the process-fabric worker fleet **once** ([`crate::par::ProcessFleet`])
//!   and keeps it warm, schedules queued jobs one at a time across it, and
//!   drains gracefully on `SHUTDOWN` or `SIGTERM`;
//! - [`queue::JobQueue`] — the FIFO of pending jobs (`CANCEL` removes
//!   exactly the targeted pending entry);
//! - [`cache::ResultCache`] — a bounded LRU keyed by
//!   `(database digest, α, GlbParams, screen mode)`; a repeat submission
//!   is answered without the workers receiving a single frame;
//! - [`client::Client`] — the typed client the `parlamp
//!   submit|status|results|shutdown` subcommands drive.
//!
//! The wire grammar of the job frames lives in [`crate::wire::service`];
//! the daemon and its clients share [`crate::wire`]'s framing, bounds
//! checking, and versioning.

pub mod cache;
pub mod client;
pub mod queue;
pub mod server;

pub use cache::{CacheKey, ResultCache};
pub use client::Client;
pub use queue::JobQueue;
pub use server::{print_join_commands, serve, ServeConfig};
