//! Discrete-event engine: P virtual processes, one real core.
//!
//! Drives the identical [`Worker`] protocol code under virtual time. Each
//! worker's expansions execute for real (the tree, the steals, the λ
//! updates are the true dynamics); time is charged from the expansion work
//! counters through a calibrated `ns_per_unit`, and the network charges
//! the [`NetModel`]'s latency + bandwidth. This is the TSUBAME
//! substitution that regenerates Figs. 6–7 at P up to 1,200 (DESIGN.md §2).

use crate::db::Database;
use crate::fabric::sim::{EventKind, EventQueue, NetModel, SimMailbox};
use crate::fabric::CommStats;
use crate::lcm::SupportHist;
use crate::obs::trace::{EventKind as TraceEv, RankTrace};

use super::breakdown::Breakdown;
use super::worker::{Poll, RunMode, Worker, WorkerConfig};
use super::ParRunResult;

/// Simulation parameters.
#[derive(Clone, Debug)]
pub struct SimConfig {
    pub p: usize,
    pub net: NetModel,
    /// Virtual nanoseconds per expansion cost unit. Calibrate against a
    /// measured serial run for absolute-time fidelity (benches do).
    pub ns_per_unit: f64,
    /// Work budget between probes, in cost units (≈1 ms, §4.6).
    pub probe_budget_units: u64,
    pub dtd_interval_ns: u64,
    /// Random steal attempts `w` (paper: 1).
    pub w: usize,
    /// Hypercube edge length `l` (paper: 2).
    pub l: usize,
    /// DTD spanning-tree arity (paper: 3).
    pub tree_arity: usize,
    /// `false` = naive baseline (no stealing).
    pub steal: bool,
    /// Depth-1 preprocess partition (§4.5).
    pub preprocess: bool,
    pub seed: u64,
}

impl SimConfig {
    /// Calibrated configuration for a measured problem: the probe cadence
    /// and wave interval scale with the measured serial time so the
    /// *ratios* (work-per-probe, waves-per-run) match the paper's regime
    /// on the scaled-down datasets. Absolute knobs clamp to the paper's
    /// values (≈1 ms probe, 1 ms waves) for large problems.
    pub fn calibrated(p: usize, cal: &crate::bench::Calibration) -> Self {
        let t1_ns = cal.t1_s * 1e9;
        let probe_ns = (t1_ns / 100_000.0).clamp(2_000.0, 1_000_000.0);
        // λ staleness wastes ≈ P · interval · (#λ-steps) of fleet work, so
        // the wave cadence scales inversely with P to bound that waste at
        // ~5% of t₁ (clamped to the paper's 1 ms above, 20 µs below).
        let dtd_ns = (0.005 * t1_ns / p as f64).clamp(20_000.0, 1_000_000.0);
        SimConfig {
            ns_per_unit: cal.ns_per_unit,
            probe_budget_units: (probe_ns / cal.ns_per_unit).max(1.0) as u64,
            dtd_interval_ns: dtd_ns as u64,
            ..Self::paper_defaults(p)
        }
    }

    pub fn paper_defaults(p: usize) -> Self {
        SimConfig {
            p,
            net: NetModel::default(),
            ns_per_unit: 0.25,
            probe_budget_units: 4_000_000,
            dtd_interval_ns: 1_000_000,
            w: 1,
            l: 2,
            tree_arity: 3,
            steal: true,
            preprocess: true,
            seed: 2015,
        }
    }
}

/// Run one phase (per `mode`) on the simulated machine; returns the merged
/// results and per-process breakdowns.
pub fn run_sim(db: &Database, mode: RunMode, cfg: &SimConfig) -> ParRunResult {
    let p = cfg.p;
    assert!(p >= 1);
    let mut workers: Vec<Worker> = (0..p)
        .map(|rank| {
            let wc = WorkerConfig {
                rank,
                p,
                w: cfg.w,
                l: cfg.l,
                tree_arity: cfg.tree_arity,
                steal: cfg.steal,
                preprocess: cfg.preprocess && p > 1,
                mode,
                probe_budget_units: cfg.probe_budget_units,
                dtd_interval_ns: cfg.dtd_interval_ns,
                ns_per_unit: Some(cfg.ns_per_unit),
                seed: cfg.seed,
            };
            Worker::new(db, wc)
        })
        .collect();
    for w in &mut workers {
        w.trace_event(TraceEv::PhaseStart { phase: mode.phase_no(), epoch: 0 });
    }
    let mut boxes: Vec<SimMailbox> = (0..p).map(|r| SimMailbox::new(r, p)).collect();
    let mut queue = EventQueue::new();
    let mut poll_scheduled = vec![false; p];
    let mut done = vec![false; p];
    let mut finish_at = vec![0u64; p];
    let mut n_done = 0usize;

    for r in 0..p {
        queue.push(0, r, EventKind::Poll);
        poll_scheduled[r] = true;
    }

    let mut now = 0u64;
    while let Some(ev) = queue.pop() {
        now = ev.time_ns;
        let r = ev.dst;
        match ev.kind {
            EventKind::Deliver { src, msg } => {
                if done[r] {
                    continue; // late messages to a finished process
                }
                boxes[r].inbox.push_back((src, msg));
                if !poll_scheduled[r] {
                    poll_scheduled[r] = true;
                    queue.push(now + cfg.net.sw_overhead_ns, r, EventKind::Poll);
                }
            }
            EventKind::Poll => {
                poll_scheduled[r] = false;
                if done[r] {
                    continue;
                }
                let outcome = workers[r].poll(&mut boxes[r], now);
                // Route outgoing messages through the network model.
                let outgoing = std::mem::take(&mut boxes[r].outbox);
                for (dst, msg) in outgoing {
                    let arrive = now + cfg.net.transit_ns(msg.wire_bytes());
                    queue.push(arrive, dst, EventKind::Deliver { src: r, msg });
                }
                match outcome {
                    Poll::Busy { cost_ns } => {
                        poll_scheduled[r] = true;
                        queue.push(now + cost_ns.max(1), r, EventKind::Poll);
                    }
                    Poll::Idle { wake_at } => {
                        if let Some(t) = wake_at {
                            poll_scheduled[r] = true;
                            queue.push(t.max(now + 1), r, EventKind::Poll);
                        }
                    }
                    Poll::Finished => {
                        workers[r]
                            .trace_event(TraceEv::PhaseEnd { phase: mode.phase_no(), epoch: 0 });
                        done[r] = true;
                        finish_at[r] = now;
                        n_done += 1;
                    }
                }
            }
        }
    }
    assert_eq!(
        n_done, p,
        "simulation deadlock: {}/{} processes finished at t={now}ns \
         (stacks: {:?})",
        n_done,
        p,
        workers.iter().map(|w| w.stack_len()).collect::<Vec<_>>()
    );

    let makespan_ns = finish_at.iter().copied().max().unwrap_or(now).max(now);
    collect(db, workers, makespan_ns, mode)
}

/// Merge worker-local results into a [`ParRunResult`].
///
/// Shared by the sim and thread engines; both run in one address space, so
/// harvested traces carry offset 0 (every rank already reads one clock).
pub(crate) fn collect(
    db: &Database,
    mut workers: Vec<Worker>,
    makespan_ns: u64,
    mode: RunMode,
) -> ParRunResult {
    let mut hist = SupportHist::new(db.n_trans());
    let mut closed_total = 0u64;
    let mut comm = CommStats::default();
    let mut work_units = 0u64;
    let mut breakdowns: Vec<Breakdown> = Vec::with_capacity(workers.len());
    let mut traces: Vec<RankTrace> = Vec::new();
    for w in &mut workers {
        hist.merge(w.hist());
        closed_total += w.closed_count();
        comm.add(&w.comm);
        work_units += w.work_units();
        let mut b = w.breakdown;
        b.close_over_span(makespan_ns);
        breakdowns.push(b);
        if let Some((events, dropped)) = w.take_trace() {
            traces.push(RankTrace {
                rank: w.rank() as u32,
                offset_ns: 0,
                uncertainty_ns: 0,
                dropped,
                events,
            });
        }
    }
    let (lambda_final, min_sup) = match mode {
        RunMode::Phase1 { .. } => (0, 0), // finalized by finalize_phase1
        RunMode::Count { min_sup } => (min_sup + 1, min_sup),
    };
    ParRunResult {
        lambda_final,
        min_sup,
        hist,
        closed_total,
        makespan_s: makespan_ns as f64 * 1e-9,
        breakdowns,
        comm,
        work_units,
        traces,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::Item;
    use crate::lamp::{lamp_serial, SupportIncreaseRule};
    use crate::util::rng::Rng;

    fn random_db(rng: &mut Rng, m: usize, n: usize, density: f64) -> Database {
        let trans: Vec<Vec<Item>> = (0..n)
            .map(|_| (0..m as Item).filter(|_| rng.bernoulli(density)).collect())
            .collect();
        let labels: Vec<bool> = (0..n).map(|t| t < n / 3).collect();
        Database::from_transactions(m, &trans, &labels)
    }

    #[test]
    fn sim_phase1_matches_serial_small_worlds() {
        let mut rng = Rng::new(77);
        for p in [1usize, 2, 3, 5, 8] {
            let db = random_db(&mut rng, 12, 30, 0.4);
            let serial = lamp_serial(&db, 0.05);
            let cfg = SimConfig { p, ..SimConfig::paper_defaults(p) };
            let rule = SupportIncreaseRule::new(db.marginals(), 0.05);
            let mut got = run_sim(&db, RunMode::Phase1 { alpha: 0.05 }, &cfg);
            got.finalize_phase1(&rule);
            assert_eq!(
                got.lambda_final, serial.lambda_final,
                "p={p}: λ mismatch (sim {} serial {})",
                got.lambda_final, serial.lambda_final
            );
            // Histogram exact at and above λ_final.
            for l in got.lambda_final..=db.n_trans() as u32 {
                // serial hist unavailable here; compare via phase-2 count below
                let _ = l;
            }
            let count_cfg = SimConfig { p, ..SimConfig::paper_defaults(p) };
            let p2 = run_sim(&db, RunMode::Count { min_sup: got.min_sup }, &count_cfg);
            assert_eq!(
                p2.closed_total, serial.correction_factor,
                "p={p}: phase-2 count mismatch"
            );
        }
    }

    #[test]
    fn sim_is_deterministic() {
        let mut rng = Rng::new(3);
        let db = random_db(&mut rng, 10, 24, 0.45);
        let cfg = SimConfig::paper_defaults(6);
        let a = run_sim(&db, RunMode::Phase1 { alpha: 0.05 }, &cfg);
        let b = run_sim(&db, RunMode::Phase1 { alpha: 0.05 }, &cfg);
        assert_eq!(a.makespan_s, b.makespan_s);
        assert_eq!(a.closed_total, b.closed_total);
        assert_eq!(a.comm.sent, b.comm.sent);
    }

    #[test]
    fn naive_mode_counts_equal_glb() {
        let mut rng = Rng::new(9);
        let db = random_db(&mut rng, 12, 28, 0.45);
        let glb = SimConfig::paper_defaults(4);
        let naive = SimConfig { steal: false, ..SimConfig::paper_defaults(4) };
        let a = run_sim(&db, RunMode::Count { min_sup: 2 }, &glb);
        let b = run_sim(&db, RunMode::Count { min_sup: 2 }, &naive);
        assert_eq!(a.closed_total, b.closed_total, "result must not depend on stealing");
        assert_eq!(b.comm.gives, 0, "naive mode must never ship tasks");
    }
}
