//! Micro-benchmarks of the hot paths (the §Perf working set):
//! AND+popcount, node expansion, Fisher P-values, stack split, DES event
//! throughput.
//!
//! Run: `cargo bench --bench micro`

use parlamp::bench::all_scenarios;
use parlamp::bits::{and_popcount, BitVec};
use parlamp::lcm::{expand, ExpandScratch, SearchNode};
use parlamp::stats::{FisherTable, Marginals};
use parlamp::util::bench_harness::{bench, BenchSet};
use parlamp::util::rng::Rng;

fn main() {
    let mut set = BenchSet::new("micro — hot paths", &["bench", "mean ± sd", "throughput"]);
    let mut rng = Rng::new(7);

    // AND + popcount over a HapMap-like row (697 transactions = 11 words)
    // and an MCF7-like row (12,773 transactions = 200 words). 1k calls per
    // sample so the timer floor doesn't dominate sub-µs kernels.
    const REPS: usize = 1000;
    for (label, words) in [("and_popcount 11w", 11usize), ("and_popcount 200w", 200)] {
        let a: Vec<u64> = (0..words).map(|_| rng.next_u64()).collect();
        let b: Vec<u64> = (0..words).map(|_| rng.next_u64()).collect();
        let s = bench(20, 500, || {
            let mut acc = 0u32;
            for _ in 0..REPS {
                acc = acc.wrapping_add(and_popcount(std::hint::black_box(&a), &b));
            }
            acc
        });
        set.row(vec![
            label.to_string(),
            format!("{:.1} ns/call", s.mean_s * 1e9 / REPS as f64),
            format!("{:.1} Gword/s", (words * REPS) as f64 / s.mean_s / 1e9),
        ]);
    }

    // Full node expansion on the hapmap-dom-10 scenario root.
    let db = all_scenarios(true).into_iter().find(|s| s.name == "hapmap-dom-10").unwrap().build();
    let mut scratch = ExpandScratch::default();
    let s = bench(3, 30, || {
        let mut root = SearchNode::root(&db);
        let mut out = Vec::new();
        expand(&db, &mut root, 2, &mut scratch, &mut out);
        out.len()
    });
    set.row(vec!["expand(root, hapmap-dom-10)".into(), s.display(), String::new()]);

    // Fisher exact test.
    let fisher = FisherTable::new(Marginals::new(697, 105));
    let s = bench(100, 5000, || {
        let mut acc = 0.0;
        for x in 1..=40u32 {
            acc += fisher.log_p_value(x, x.min(20));
        }
        acc
    });
    set.row(vec![
        "fisher log_p ×40".into(),
        s.display(),
        format!("{:.2} Mp/s", 40.0 / s.mean_s / 1e6),
    ]);

    // Stack split (steal GIVE path).
    let nodes: Vec<SearchNode> = (0..512)
        .map(|i| SearchNode {
            items: vec![i as u32, i as u32 + 1, i as u32 + 2],
            core: i as i64,
            support: 5,
            occ: Some(BitVec::ones(697)),
        })
        .collect();
    let s = bench(100, 3000, || {
        let mut stack = nodes.clone();
        let half: Vec<SearchNode> = stack.drain(..stack.len() / 2).collect();
        half.len() + stack.len()
    });
    set.row(vec!["split 512-node stack".into(), s.display(), String::new()]);

    set.finish();
}
