//! Chaos suite (DESIGN.md §12): deterministic fault injection proves the
//! process fleet survives worker death with *bit-identical* results.
//!
//! Every test arms a [`FaultPlan`] — rank R exits with code 86 at a
//! planned point — and asserts the three-phase LAMP outcome equals the
//! serial reference exactly: λ*, both closed-pattern histograms, the
//! correction factor k, and the significant set. The kill-mid-phase tests
//! run on every {data plane × transport} combination and pin "exactly one
//! respawn"; a kill *after* the rank's last merge (while the owner runs
//! the serial phase-3 screen) must be absorbed with *zero* mid-phase
//! recoveries; and the `parlamp serve` daemon must finish an in-flight
//! job across a worker death.
//!
//! The §15 network-fault matrix extends the same contract to ranks that
//! misbehave *without dying*: a [`NetFaultPlan`] arms `stall`, `partition`,
//! `drop`, or `corrupt` against rank 1's streams, scripted by frame counts
//! rather than wall time. Stall/partition/drop are caught by heartbeat
//! lease expiry (force-kill + respawn through the same replay path);
//! corrupt is caught at the hub's frame decoder. Every kind runs on the
//! {data plane × transport} grid and must end bit-identical to serial with
//! exactly one respawn.
//!
//! A property test rides along: a `SearchNode` shipped over the real wire
//! (strip → GIVE frame → decode → occurrence-bitmap rebuild) re-expands
//! to the identical closed-set sequence, and two replays of the shipped
//! copy agree on the work-unit clock — the determinism the respawn/replay
//! recovery leans on.

use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use parlamp::datagen::{generate_gwas, GeneticModel, GwasSpec};
use parlamp::db::{Database, Item};
use parlamp::fabric::{BasicKind, Msg, WireTask};
use parlamp::lamp::{lamp_serial, phase3_extract, SupportIncreaseRule};
use parlamp::lcm::{expand, mine_closed, ExpandScratch, SearchNode, SupportHist, Visit};
use parlamp::net::Endpoint;
use parlamp::par::{
    DataPlane, FaultPlan, NetFaultKind, NetFaultPlan, ProcessConfig, ProcessFleet, RunMode,
};
use parlamp::service::Client;
use parlamp::util::propcheck::forall_sized;
use parlamp::wire::service::{JobOutcome, JobSpec};
use parlamp::wire::Frame;

fn parlamp_bin() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_parlamp"))
}

/// The quickstart cohort (200 SNPs × 150 individuals, one planted 3-SNP
/// association) — the same dataset the equivalence suite and the CI smoke
/// jobs mine.
fn quickstart_db() -> Database {
    let spec = GwasSpec {
        n_snps: 200,
        n_individuals: 150,
        n_pos: 40,
        model: GeneticModel::Dominant,
        maf_upper: 0.2,
        ld_copy_prob: 0.25,
        common_frac: 0.2,
        planted: vec![(3, 0.9)],
        seed: 31,
    };
    generate_gwas(&spec).0
}

/// Serial closed-pattern histogram at `min_sup` — the bit-exact oracle.
fn serial_hist(db: &Database, min_sup: u32) -> SupportHist {
    let mut hist = SupportHist::new(db.n_trans());
    mine_closed(db, min_sup, |node, ms| {
        hist.record(node.support);
        (Visit::Continue, ms)
    });
    hist
}

/// Fleet config for the kill-mid-phase tests. The probe budget is cut to
/// 50 k units (paper default: 4 M) so each phase spans many mailbox polls:
/// the fault check sits at the top of the worker's poll loop, and a budget
/// that swallows the whole quickstart phase in one quantum would demote
/// the "mid-phase" death to a post-merge one.
fn chaos_cfg(plane: DataPlane, listen: Option<Endpoint>, seed: u64) -> ProcessConfig {
    ProcessConfig {
        worker_exe: Some(parlamp_bin()),
        spawn_timeout: Duration::from_secs(60),
        data_plane: plane,
        listen,
        probe_budget_units: 50_000,
        fault: Some(FaultPlan { rank: 1, phase: 0, after: 1 }),
        ..ProcessConfig::paper_defaults(3, seed)
    }
}

/// Fleet config for the network-fault tests (DESIGN.md §15): same shape
/// as [`chaos_cfg`], but instead of killing rank 1 it stalls, partitions,
/// drops, or corrupts its fabric traffic after the first data frame of
/// phase epoch 0. The 3 s lease timeout (paper default: 60 s) keeps the
/// silent-rank detection fast enough for a test.
fn net_chaos_cfg(
    kind: NetFaultKind,
    plane: DataPlane,
    listen: Option<Endpoint>,
    seed: u64,
) -> ProcessConfig {
    ProcessConfig {
        worker_exe: Some(parlamp_bin()),
        spawn_timeout: Duration::from_secs(60),
        data_plane: plane,
        listen,
        probe_budget_units: 50_000,
        net_fault: Some(NetFaultPlan { rank: 1, kind, phase: 0, after: 1 }),
        lease_timeout: Duration::from_secs(3),
        ..ProcessConfig::paper_defaults(3, seed)
    }
}

/// The core acceptance: kill rank 1 mid-way through phase 1, and the
/// three-phase run must still equal the serial reference bit for bit,
/// with exactly one respawn over the fleet's lifetime.
fn kill_mid_phase_and_verify(plane: DataPlane, listen: Option<Endpoint>) {
    chaos_run_and_verify(chaos_cfg(plane, listen, 42));
}

/// The §15 counterpart: rank 1's *network* misbehaves mid-phase — it goes
/// silent (stall), answers nothing on its main thread (partition), loses
/// every hub-bound frame (drop), or ships a corrupted frame. The hub's
/// heartbeat lease (or, for corrupt, the decode error) must detect it,
/// force-kill exactly that rank, and replay to bit-identical results.
fn net_fault_and_verify(kind: NetFaultKind, plane: DataPlane, listen: Option<Endpoint>) {
    chaos_run_and_verify(net_chaos_cfg(kind, plane, listen, 42));
}

/// Shared acceptance body: run the three phases on a fleet whose `cfg`
/// has one fault armed against rank 1 in phase epoch 0, and assert the
/// serial-identical outcome plus exactly one respawn.
fn chaos_run_and_verify(cfg: ProcessConfig) {
    let db = quickstart_db();
    let serial = lamp_serial(&db, 0.05);
    let rule = SupportIncreaseRule::new(db.marginals(), 0.05);
    let mut fleet = ProcessFleet::spawn(&cfg).expect("spawn fleet");

    // Phase 1 (λ search): epoch 0 is the attempt the fault voids; the
    // replay runs under epoch 1 with the respawned rank 1 re-CONFIGured.
    let mut p1 = fleet
        .run_phase(&db, RunMode::Phase1 { alpha: 0.05 }, &cfg, 42)
        .expect("phase 1 must survive the injected death");
    assert_eq!(fleet.respawns(), 1, "exactly one rank must have been respawned");
    p1.finalize_phase1(&rule);
    assert_eq!(p1.lambda_final, serial.lambda_final, "λ* differs after recovery");
    assert_eq!(p1.min_sup, serial.min_sup);
    // The phase-1 merge is exact at and above λ* (DESIGN.md §4).
    let oracle1 = serial_hist(&db, serial.lambda_final);
    for support in serial.lambda_final..=db.n_trans() as u32 {
        assert_eq!(
            p1.hist.counts()[support as usize],
            oracle1.counts()[support as usize],
            "phase-1 histogram differs at support {support} after recovery"
        );
    }

    // Phase 2 (count at min_sup): runs on the healed fleet; no further
    // deaths, no further respawns.
    let p2 = fleet
        .run_phase(&db, RunMode::Count { min_sup: p1.min_sup }, &cfg, 43)
        .expect("phase 2 on the healed fleet");
    assert_eq!(fleet.respawns(), 1, "the fault fires exactly once");
    assert_eq!(p2.closed_total, serial.correction_factor, "k differs after recovery");
    assert_eq!(
        p2.hist.counts(),
        serial_hist(&db, serial.min_sup).counts(),
        "phase-2 closed-pattern histogram differs after recovery"
    );

    // Phase 3 (serial screen at α/k), composed exactly as the coordinator
    // composes it: the significant set must match the undisturbed run.
    let k = p2.closed_total.max(1);
    let significant = phase3_extract(&db, p1.min_sup, k, 0.05);
    assert_eq!(significant.len(), serial.significant.len(), "significant set size differs");
    for (a, b) in significant.iter().zip(&serial.significant) {
        assert_eq!(a.items, b.items, "significant set differs after recovery");
        assert!((a.p_value - b.p_value).abs() < 1e-12);
    }
    fleet.shutdown().expect("clean shutdown after recovery");
}

#[test]
fn killed_worker_recovers_bit_identical_hub_unix() {
    kill_mid_phase_and_verify(DataPlane::Hub, None);
}

#[test]
fn killed_worker_recovers_bit_identical_mesh_unix() {
    kill_mid_phase_and_verify(DataPlane::Mesh, None);
}

#[test]
fn killed_worker_recovers_bit_identical_hub_tcp() {
    kill_mid_phase_and_verify(DataPlane::Hub, Some(Endpoint::tcp("127.0.0.1", 0)));
}

#[test]
fn killed_worker_recovers_bit_identical_mesh_tcp() {
    kill_mid_phase_and_verify(DataPlane::Mesh, Some(Endpoint::tcp("127.0.0.1", 0)));
}

// --- Network faults (DESIGN.md §15): a rank that misbehaves without dying.
//
// `stall` parks the whole worker (main thread and reader) at its first
// data-plane send; `partition` parks only the main thread, so the process
// still *reads* from the hub but can answer nothing — the case EOF-based
// detection can never catch; `drop` silently discards every hub-bound
// frame from then on; `corrupt` flips the tag byte of one hub-bound
// frame. The first three are detected by heartbeat-lease expiry
// (force-kill + respawn); corrupt is detected at the hub's decoder (Gone
// + respawn). All four must end bit-identical to the serial reference
// with exactly one respawn — on every {data plane × transport} combo.

#[test]
fn stalled_worker_recovers_bit_identical_hub_unix() {
    net_fault_and_verify(NetFaultKind::Stall, DataPlane::Hub, None);
}

#[test]
fn stalled_worker_recovers_bit_identical_mesh_unix() {
    net_fault_and_verify(NetFaultKind::Stall, DataPlane::Mesh, None);
}

#[test]
fn stalled_worker_recovers_bit_identical_hub_tcp() {
    net_fault_and_verify(NetFaultKind::Stall, DataPlane::Hub, Some(Endpoint::tcp("127.0.0.1", 0)));
}

#[test]
fn stalled_worker_recovers_bit_identical_mesh_tcp() {
    net_fault_and_verify(NetFaultKind::Stall, DataPlane::Mesh, Some(Endpoint::tcp("127.0.0.1", 0)));
}

#[test]
fn partitioned_worker_recovers_bit_identical_hub_unix() {
    net_fault_and_verify(NetFaultKind::Partition, DataPlane::Hub, None);
}

#[test]
fn partitioned_worker_recovers_bit_identical_mesh_unix() {
    net_fault_and_verify(NetFaultKind::Partition, DataPlane::Mesh, None);
}

#[test]
fn partitioned_worker_recovers_bit_identical_hub_tcp() {
    net_fault_and_verify(
        NetFaultKind::Partition,
        DataPlane::Hub,
        Some(Endpoint::tcp("127.0.0.1", 0)),
    );
}

#[test]
fn partitioned_worker_recovers_bit_identical_mesh_tcp() {
    net_fault_and_verify(
        NetFaultKind::Partition,
        DataPlane::Mesh,
        Some(Endpoint::tcp("127.0.0.1", 0)),
    );
}

#[test]
fn corrupt_frame_recovers_bit_identical_hub_unix() {
    net_fault_and_verify(NetFaultKind::Corrupt, DataPlane::Hub, None);
}

#[test]
fn corrupt_frame_recovers_bit_identical_mesh_unix() {
    net_fault_and_verify(NetFaultKind::Corrupt, DataPlane::Mesh, None);
}

#[test]
fn corrupt_frame_recovers_bit_identical_hub_tcp() {
    net_fault_and_verify(
        NetFaultKind::Corrupt,
        DataPlane::Hub,
        Some(Endpoint::tcp("127.0.0.1", 0)),
    );
}

#[test]
fn corrupt_frame_recovers_bit_identical_mesh_tcp() {
    net_fault_and_verify(
        NetFaultKind::Corrupt,
        DataPlane::Mesh,
        Some(Endpoint::tcp("127.0.0.1", 0)),
    );
}

/// `drop` keeps rank 1 mining — and stealing, on the mesh plane — while
/// every frame it owes the hub (PONGs, checkpoints, its merge) vanishes.
/// From the hub's chair that is indistinguishable from a partition, and
/// the lease expiry must resolve it the same way.
#[test]
fn dropped_hub_frames_recover_bit_identical_mesh_unix() {
    net_fault_and_verify(NetFaultKind::Drop, DataPlane::Mesh, None);
}

/// A worker killed *after* its last merge — the owner is off running the
/// serial phase-3 screen, no distributed phase is active — must not cost
/// a replay: the results stand, no mid-phase recovery runs, and shutdown
/// absorbs the distinctive exit code.
#[test]
fn death_after_last_merge_is_absorbed_without_replay() {
    let db = quickstart_db();
    let serial = lamp_serial(&db, 0.05);
    let rule = SupportIncreaseRule::new(db.marginals(), 0.05);
    // phase=1 arms the plan for epoch 1 (= phase 2); `after` is
    // unreachable, so the mid-phase trigger never fires and the rank dies
    // at the post-merge trigger instead — right after its phase-2 merge.
    let cfg = ProcessConfig {
        worker_exe: Some(parlamp_bin()),
        spawn_timeout: Duration::from_secs(60),
        fault: Some(FaultPlan { rank: 1, phase: 1, after: u64::MAX }),
        ..ProcessConfig::paper_defaults(3, 42)
    };
    let mut fleet = ProcessFleet::spawn(&cfg).expect("spawn fleet");
    let mut p1 =
        fleet.run_phase(&db, RunMode::Phase1 { alpha: 0.05 }, &cfg, 42).expect("phase 1");
    p1.finalize_phase1(&rule);
    let p2 = fleet
        .run_phase(&db, RunMode::Count { min_sup: p1.min_sup }, &cfg, 43)
        .expect("phase 2 completes although rank 1 dies after its merge");
    assert_eq!(p1.lambda_final, serial.lambda_final);
    assert_eq!(p2.closed_total, serial.correction_factor);
    assert_eq!(p2.hist.counts(), serial_hist(&db, serial.min_sup).counts());
    let significant = phase3_extract(&db, p1.min_sup, p2.closed_total.max(1), 0.05);
    assert_eq!(significant.len(), serial.significant.len());
    // The death postdates every contribution the run needed: no replay,
    // no respawn — and the teardown tolerates exit code 86.
    assert_eq!(fleet.respawns(), 0, "a post-merge death must not trigger recovery");
    fleet.shutdown().expect("shutdown absorbs the injected exit code");
}

/// `parlamp serve` keeps its promise across a worker death: the in-flight
/// job completes with serial-identical results, the daemon's warm fleet
/// respawns exactly one rank, and shutdown still exits 0.
#[test]
fn daemon_finishes_in_flight_job_across_worker_death() {
    let db = {
        let spec = GwasSpec {
            n_snps: 120,
            n_individuals: 90,
            n_pos: 24,
            model: GeneticModel::Dominant,
            maf_upper: 0.2,
            ld_copy_prob: 0.25,
            common_frac: 0.2,
            planted: vec![(3, 0.9)],
            seed: 47,
        };
        generate_gwas(&spec).0
    };
    let serial = lamp_serial(&db, 0.05);
    let hist = {
        let mut h = SupportHist::new(db.n_trans());
        mine_closed(&db, serial.min_sup, |node, ms| {
            h.record(node.support);
            (Visit::Continue, ms)
        });
        h.sparse()
    };

    let dir = std::env::temp_dir().join(format!("parlamp-chaos-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let socket = dir.join("parlamp.sock");
    let stderr_path = dir.join("serve.stderr");
    let stderr_file = std::fs::File::create(&stderr_path).expect("create stderr capture");
    // The daemon's stderr (hub recovery lines) and its workers' stderr
    // (the fault's own line) both land in the capture file: workers
    // inherit the daemon's stderr.
    let child = Command::new(parlamp_bin())
        .arg("serve")
        .arg("--socket")
        .arg(&socket)
        .arg("--procs")
        .arg("3")
        .arg("--cache")
        .arg("4")
        .arg("--fault-inject")
        .arg("rank=1,phase=0,after=1")
        .stdin(Stdio::null())
        .stdout(Stdio::null())
        .stderr(Stdio::from(stderr_file))
        .spawn()
        .expect("spawn parlamp serve with fault injection");
    struct KillOnDrop(Option<Child>);
    impl Drop for KillOnDrop {
        fn drop(&mut self) {
            if let Some(mut c) = self.0.take() {
                let _ = c.kill();
                let _ = c.wait();
            }
        }
    }
    let mut guard = KillOnDrop(Some(child));
    let deadline = Instant::now() + Duration::from_secs(60);
    while !socket.exists() {
        assert!(Instant::now() < deadline, "daemon never bound its socket");
        std::thread::sleep(Duration::from_millis(10));
    }

    // One job; its phase 1 runs at epoch 0, where the armed rank dies. The
    // job must still come back serial-identical.
    let ep = Endpoint::unix(&socket);
    let mut client = Client::connect(&ep).expect("connect to daemon");
    let id = client.submit(JobSpec::new(db.clone(), 0.05)).expect("submit");
    let outcome: JobOutcome = client.results(id).expect("job must finish across the death");
    assert!(!outcome.from_cache);
    assert_eq!(outcome.lambda_final, serial.lambda_final, "λ* differs across worker death");
    assert_eq!(outcome.min_sup, serial.min_sup);
    assert_eq!(outcome.correction_factor, serial.correction_factor);
    assert_eq!(outcome.phase2_closed, serial.phase2_closed);
    assert_eq!(outcome.hist2, hist, "phase-2 histogram differs across worker death");
    assert_eq!(outcome.significant.len(), serial.significant.len());
    for (a, b) in outcome.significant.iter().zip(&serial.significant) {
        assert_eq!(a.items, b.items);
        assert!((a.p_value - b.p_value).abs() < 1e-12);
    }

    // Graceful shutdown still works on the healed fleet.
    client.shutdown().expect("shutdown ack");
    let mut child = guard.0.take().expect("daemon still owned");
    let deadline = Instant::now() + Duration::from_secs(60);
    let status = loop {
        if let Some(status) = child.try_wait().expect("poll daemon") {
            break status;
        }
        assert!(Instant::now() < deadline, "daemon did not exit after shutdown");
        std::thread::sleep(Duration::from_millis(20));
    };
    assert!(status.success(), "daemon exit: {status}");

    // Log shape: the fault fired (worker line), and the hub respawned
    // exactly one rank — the plan never travels to a replacement. Both
    // lines now ride the structured logger (DESIGN.md §14), so the shape
    // `parlamp[LEVEL target tags]` is part of the contract too.
    let log = std::fs::read_to_string(&stderr_path).expect("read stderr capture");
    assert!(
        log.contains("fault injection firing"),
        "worker fault line missing from daemon stderr:\n{log}"
    );
    assert!(
        log.contains("parlamp[WARN worker rank=1]"),
        "fault line lost its structured rank tag:\n{log}"
    );
    assert_eq!(
        log.matches("respawning rank 1").count(),
        1,
        "expected exactly one respawn of rank 1 in daemon stderr:\n{log}"
    );
    assert!(
        log.contains("parlamp[WARN fleet rank=1]"),
        "respawn line lost its structured rank tag:\n{log}"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// Pool chaos (DESIGN.md §13): a 2-fleet daemon where fleet 0 has a
/// worker death armed. Two clients submit different jobs concurrently;
/// the job that lands on fleet 0 rides the PR-7 recovery (one respawned
/// rank), the other fleet's job is untouched — and BOTH results must be
/// bit-identical to the serial reference. STATS must agree: two fleets,
/// two jobs mined, exactly one respawn across the pool.
#[test]
fn pool_survives_one_fleets_worker_death() {
    let db = {
        let spec = GwasSpec {
            n_snps: 120,
            n_individuals: 90,
            n_pos: 24,
            model: GeneticModel::Dominant,
            maf_upper: 0.2,
            ld_copy_prob: 0.25,
            common_frac: 0.2,
            planted: vec![(3, 0.9)],
            seed: 47,
        };
        generate_gwas(&spec).0
    };
    // Two distinct α values ⇒ two distinct cache keys ⇒ both jobs mine.
    let alphas = [0.05, 0.01];
    let serials: Vec<_> = alphas.iter().map(|a| lamp_serial(&db, *a)).collect();

    let dir = std::env::temp_dir().join(format!("parlamp-poolchaos-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let socket = dir.join("parlamp.sock");
    let stderr_path = dir.join("serve.stderr");
    let stderr_file = std::fs::File::create(&stderr_path).expect("create stderr capture");
    let child = Command::new(parlamp_bin())
        .arg("serve")
        .arg("--socket")
        .arg(&socket)
        .arg("--procs")
        .arg("3")
        .arg("--fleets")
        .arg("2")
        .arg("--fault-inject")
        .arg("rank=1,phase=0,after=1")
        .stdin(Stdio::null())
        .stdout(Stdio::null())
        .stderr(Stdio::from(stderr_file))
        .spawn()
        .expect("spawn 2-fleet parlamp serve with fault injection");
    struct KillOnDrop(Option<Child>);
    impl Drop for KillOnDrop {
        fn drop(&mut self) {
            if let Some(mut c) = self.0.take() {
                let _ = c.kill();
                let _ = c.wait();
            }
        }
    }
    let mut guard = KillOnDrop(Some(child));
    let deadline = Instant::now() + Duration::from_secs(120);
    while !socket.exists() {
        assert!(Instant::now() < deadline, "daemon never bound its socket");
        std::thread::sleep(Duration::from_millis(10));
    }

    // Two clients, two concurrent jobs. Each thread submits and blocks on
    // RESULT; the daemon's two runner threads mine them in parallel, so
    // the armed fleet's death overlaps the healthy fleet's job.
    let ep = Endpoint::unix(&socket);
    let outcomes: Vec<JobOutcome> = std::thread::scope(|s| {
        let handles: Vec<_> = alphas
            .iter()
            .enumerate()
            .map(|(i, alpha)| {
                let db = db.clone();
                let ep = ep.clone();
                s.spawn(move || {
                    let mut client = Client::connect(&ep).expect("connect");
                    let spec = JobSpec {
                        client: format!("tenant-{i}"),
                        ..JobSpec::new(db, *alpha)
                    };
                    let id = client.submit(spec).expect("submit");
                    client.results(id).expect("job must finish across the death")
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("client thread")).collect()
    });

    for (i, (outcome, serial)) in outcomes.iter().zip(&serials).enumerate() {
        assert!(!outcome.from_cache, "job {i} must have been mined, not cached");
        assert_eq!(outcome.lambda_final, serial.lambda_final, "λ* differs for job {i}");
        assert_eq!(outcome.min_sup, serial.min_sup, "min_sup differs for job {i}");
        assert_eq!(
            outcome.correction_factor, serial.correction_factor,
            "correction factor differs for job {i}"
        );
        assert_eq!(outcome.phase2_closed, serial.phase2_closed);
        assert_eq!(outcome.significant.len(), serial.significant.len());
        for (a, b) in outcome.significant.iter().zip(&serial.significant) {
            assert_eq!(a.items, b.items, "significant set differs for job {i}");
            assert!((a.p_value - b.p_value).abs() < 1e-12);
        }
    }

    // STATS over the wire: two fleets, two mined jobs, one respawn total.
    let mut client = Client::connect(&ep).expect("connect for stats");
    let stats = client.stats().expect("STATS report");
    assert_eq!(stats.fleets.len(), 2, "pool must report both fleets");
    assert_eq!(stats.jobs_mined, 2);
    assert_eq!(
        stats.fleets.iter().map(|f| f.jobs_mined).sum::<u64>(),
        2,
        "both jobs must be accounted to fleets: {stats}"
    );
    assert_eq!(
        stats.fleets.iter().map(|f| f.respawns).sum::<u64>(),
        1,
        "exactly one rank respawn across the pool: {stats}"
    );

    client.shutdown().expect("shutdown ack");
    let mut child = guard.0.take().expect("daemon still owned");
    let deadline = Instant::now() + Duration::from_secs(120);
    let status = loop {
        if let Some(status) = child.try_wait().expect("poll daemon") {
            break status;
        }
        assert!(Instant::now() < deadline, "daemon did not exit after shutdown");
        std::thread::sleep(Duration::from_millis(20));
    };
    assert!(status.success(), "daemon exit: {status}");

    let log = std::fs::read_to_string(&stderr_path).expect("read stderr capture");
    assert!(
        log.contains("fault injection firing"),
        "worker fault line missing from daemon stderr:\n{log}"
    );
    assert_eq!(
        log.matches("respawning rank 1").count(),
        1,
        "expected exactly one respawn of rank 1 in daemon stderr:\n{log}"
    );
    assert!(
        log.contains("parlamp[WARN fleet rank=1]"),
        "respawn line lost its structured rank tag:\n{log}"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// Depth-first subtree mine from one node, recording the closed-set
/// sequence (DFS order — stricter than set equality) and the work-unit
/// clock the breakdown/DES layers charge.
fn mine_subtree(db: &Database, node: SearchNode, min_sup: u32) -> (Vec<(Vec<Item>, u32)>, u64) {
    let mut stack = vec![node];
    let mut closed = Vec::new();
    let mut units = 0u64;
    let mut scratch = ExpandScratch::default();
    while let Some(mut n) = stack.pop() {
        closed.push((n.items.clone(), n.support));
        units += expand(db, &mut n, min_sup, &mut scratch, &mut stack).units();
    }
    (closed, units)
}

/// Satellite property (DESIGN.md §12): shipping a `SearchNode` across the
/// wire is lossless for mining. For random dense and sparse databases,
/// every depth-1 subtree root is (a) mined in place with its occurrence
/// cache warm, and (b) stripped, carried through a real encoded GIVE
/// frame, rebuilt from the decoded [`WireTask`], and mined cold. The
/// closed-set sequences must be identical, and two cold replays must
/// agree on the work-unit clock — the property that makes a respawned
/// rank's replayed phase bit-identical.
#[test]
fn shipped_search_nodes_re_expand_deterministically() {
    forall_sized("shipped subtree replay is deterministic", 24, |rng, case| {
        let n_trans = 20 + rng.index(40);
        let n_items = 8 + rng.index(12);
        // Even cases dense, odd cases sparse — both bitmap regimes.
        let density = if case % 2 == 0 { 0.45 } else { 0.12 };
        let trans: Vec<Vec<Item>> = (0..n_trans)
            .map(|_| {
                (0..n_items as Item).filter(|_| rng.bernoulli(density)).collect::<Vec<_>>()
            })
            .collect();
        let labels: Vec<bool> = (0..n_trans).map(|_| rng.bernoulli(0.4)).collect();
        let db = Database::from_transactions(n_items, &trans, &labels);
        let min_sup = 1 + rng.index(3) as u32;

        let mut root = SearchNode::root(&db);
        let mut frontier = Vec::new();
        expand(&db, &mut root, min_sup, &mut ExpandScratch::default(), &mut frontier);
        for node in frontier {
            let (local_closed, _) = mine_subtree(&db, node.clone(), min_sup);

            // Ship it for real: strip the occurrence cache, ride an
            // encoded GIVE frame, decode, rebuild with a cold cache.
            let mut shipped = node.clone();
            shipped.strip_for_wire();
            let task = WireTask {
                items: shipped.items.clone(),
                core: shipped.core,
                support: shipped.support,
            };
            let frame = Frame::PeerMsg {
                src: 1,
                epoch: 3,
                msg: Msg::Basic { stamp: 0, kind: BasicKind::Give { tasks: vec![task] } },
            };
            let bytes = frame.encode();
            let decoded = Frame::decode(&bytes[4..]).map_err(|e| format!("{e:#}"))?;
            let t = match decoded {
                Frame::PeerMsg {
                    msg: Msg::Basic { kind: BasicKind::Give { mut tasks }, .. },
                    ..
                } => tasks.pop().ok_or("GIVE lost its task")?,
                other => return Err(format!("GIVE decoded as {other:?}")),
            };
            let rebuilt =
                SearchNode { items: t.items, core: t.core, support: t.support, occ: None };

            let (a_closed, a_units) = mine_subtree(&db, rebuilt.clone(), min_sup);
            let (b_closed, b_units) = mine_subtree(&db, rebuilt, min_sup);
            if a_closed != local_closed {
                return Err(format!(
                    "shipped subtree mined a different closed sequence \
                     (root {:?}): {} local vs {} shipped",
                    node.items,
                    local_closed.len(),
                    a_closed.len()
                ));
            }
            if a_closed != b_closed || a_units != b_units {
                return Err(format!(
                    "two replays of the same shipped subtree disagree \
                     (root {:?}): {a_units} vs {b_units} units",
                    node.items
                ));
            }
        }
        Ok(())
    });
}
