//! Brute-force closed-itemset enumeration — the test oracle.
//!
//! Enumerates every subset of items (so only usable for ≤ ~20 items),
//! computes its closure, and collects the distinct closed sets with support
//! ≥ `min_sup`. Quadratic and allocation-happy on purpose: it is the
//! *independent* implementation the LCM tree search is validated against.

use std::collections::BTreeSet;

use crate::db::{Database, Item};

/// All distinct non-empty-support closed itemsets with support ≥ `min_sup`,
/// sorted. Includes the closure of the empty set only if it is non-empty
/// (matching the miner, which reports the root only when non-empty).
pub fn brute_force_closed(db: &Database, min_sup: u32) -> Vec<(Vec<Item>, u32)> {
    let m = db.n_items();
    assert!(m <= 22, "brute force oracle limited to tiny databases");
    let mut seen: BTreeSet<Vec<Item>> = BTreeSet::new();
    let mut out = Vec::new();
    for mask in 0u64..(1u64 << m) {
        let items: Vec<Item> = (0..m as Item).filter(|i| mask >> i & 1 == 1).collect();
        let occ = db.occurrence(&items);
        let sup = occ.count();
        if sup < min_sup.max(1) {
            continue; // empty-support sets are never reported
        }
        // closure = all items whose column contains occ
        let closure: Vec<Item> =
            (0..m as Item).filter(|&j| occ.is_subset_of(db.col(j))).collect();
        if closure.is_empty() {
            continue; // closure of the empty set when no item is universal
        }
        if seen.insert(closure.clone()) {
            let csup = db.support(&closure);
            out.push((closure, csup));
        }
    }
    out.sort();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_example_by_hand() {
        // trans: {0,1}, {0,1}, {1}
        let db = Database::from_transactions(
            2,
            &[vec![0, 1], vec![0, 1], vec![1]],
            &[true, false, false],
        );
        let got = brute_force_closed(&db, 1);
        // closed sets: {1} (sup 3), {0,1} (sup 2)
        assert_eq!(got, vec![(vec![0, 1], 2), (vec![1], 3)]);
    }

    #[test]
    fn min_sup_filters() {
        let db = Database::from_transactions(
            2,
            &[vec![0, 1], vec![0, 1], vec![1]],
            &[true, false, false],
        );
        let got = brute_force_closed(&db, 3);
        assert_eq!(got, vec![(vec![1], 3)]);
    }
}
