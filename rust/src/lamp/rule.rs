//! The support-increase decision rule (paper Eq. 3.1).
//!
//! Shared between the serial phase 1 and the distributed root process so
//! both raise `λ` at exactly the same closed-set counts.

use crate::stats::{tarone::TaroneBound, Marginals};

/// Encapsulates the test "should λ rise given the current closed-set
/// histogram?".
///
/// Condition 3.1 holds at λ when `CS(λ) > α / f(λ−1)` (equivalently
/// `CS(λ) · f(λ−1) > α`), meaning itemsets with support < λ are untestable
/// at the adjusted level and λ may rise. At quiescence, the final λ* never
/// exceeded its threshold, so the optimal minimum support is `λ* − 1`.
#[derive(Clone, Debug)]
pub struct SupportIncreaseRule {
    alpha: f64,
    tarone: TaroneBound,
    /// Precomputed thresholds `α / f(λ−1)` indexed by λ (0 and 1 are
    /// always-exceedable sentinels; f(0) = 1 gives threshold α at λ=1).
    threshold: Vec<f64>,
}

impl SupportIncreaseRule {
    pub fn new(m: Marginals, alpha: f64) -> Self {
        assert!(alpha > 0.0 && alpha < 1.0, "alpha must be in (0,1)");
        let tarone = TaroneBound::new(m);
        let mut threshold = Vec::with_capacity(m.n as usize + 2);
        threshold.push(0.0); // λ = 0: unused
        for lambda in 1..=m.n + 1 {
            let f = tarone.f(lambda - 1).max(f64::MIN_POSITIVE);
            threshold.push(alpha / f);
        }
        SupportIncreaseRule { alpha, tarone, threshold }
    }

    pub fn alpha(&self) -> f64 {
        self.alpha
    }

    /// Threshold `α / f(λ−1)` that `CS(λ)` must stay at or below.
    pub fn threshold(&self, lambda: u32) -> f64 {
        self.threshold[lambda as usize]
    }

    /// Does condition 3.1 hold at `lambda` for the given closed-set count
    /// `cs_ge_lambda = CS(λ)` (i.e. should λ rise past it)?
    #[inline]
    pub fn exceeded(&self, lambda: u32, cs_ge_lambda: u64) -> bool {
        cs_ge_lambda as f64 > self.threshold(lambda)
    }

    /// Advance λ as far as the histogram allows; returns the new λ.
    /// `cs_ge` must report CS(λ) for any queried λ.
    pub fn advance(&self, mut lambda: u32, cs_ge: impl Fn(u32) -> u64) -> u32 {
        let max_lambda = (self.threshold.len() - 1) as u32;
        while lambda < max_lambda && self.exceeded(lambda, cs_ge(lambda)) {
            lambda += 1;
        }
        lambda
    }

    pub fn tarone(&self) -> &TaroneBound {
        &self.tarone
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn threshold_monotone_increasing_up_to_npos() {
        // f(x) is monotone non-increasing only on 0..=N_pos (beyond it the
        // all-positives-inside bound turns back up), so the threshold
        // α/f(λ−1) rises monotonically for λ−1 ≤ N_pos — the regime the
        // support-increase search actually operates in.
        let r = SupportIncreaseRule::new(Marginals::new(100, 30), 0.05);
        for l in 1..=30u32 {
            assert!(
                r.threshold(l + 1) >= r.threshold(l) * (1.0 - 1e-12),
                "threshold must rise with λ (l={l})"
            );
        }
    }

    #[test]
    fn lambda1_threshold_is_alpha() {
        // f(0) = 1 ⇒ threshold(1) = α ⇒ a single closed set (count 1 > 0.05)
        // immediately exceeds it, exactly as the Fig 2 walk-through says.
        let r = SupportIncreaseRule::new(Marginals::new(50, 20), 0.05);
        assert!((r.threshold(1) - 0.05).abs() < 1e-12);
        assert!(r.exceeded(1, 1));
    }

    #[test]
    fn advance_stops_at_first_unexceeded() {
        let r = SupportIncreaseRule::new(Marginals::new(100, 30), 0.05);
        // Fake histogram: plenty of mass at low support, nothing above 5.
        let cs = |l: u32| if l <= 5 { 1_000_000 } else { 0 };
        let got = r.advance(1, cs);
        assert_eq!(got, 6, "λ should pass all exceeded levels then stop");
        // idempotent from there
        assert_eq!(r.advance(got, cs), got);
    }

    #[test]
    #[should_panic(expected = "alpha")]
    fn rejects_bad_alpha() {
        SupportIncreaseRule::new(Marginals::new(10, 5), 1.5);
    }
}
