//! Scaling study: sweep the process count on one scenario and print the
//! Fig-6-style time/speedup series plus the Fig-7-style breakdown —
//! including the slow-network (Ethernet-class) estimate the paper only
//! discusses (§5.2). Every point is one coordinated run
//! ([`parlamp::coordinator`]) on the calibrated DES backend.
//!
//! ```bash
//! cargo run --release --example scaling_study [scenario]
//! ```

use parlamp::bench::{all_scenarios, calibrate_lamp, serial_t1};
use parlamp::coordinator::{Backend, Coordinator, ScreenMode};
use parlamp::fabric::sim::NetModel;
use parlamp::par::breakdown;
use parlamp::util::table::Table;

fn main() {
    let name = std::env::args().nth(1).unwrap_or_else(|| "hapmap-dom-10".into());
    let sc = all_scenarios(true)
        .into_iter()
        .find(|s| s.name == name)
        .unwrap_or_else(|| panic!("unknown scenario {name}; see `parlamp scenarios`"));
    let db = sc.build();
    let cal = calibrate_lamp(&db, parlamp::DEFAULT_ALPHA);
    let (t1, res) = serial_t1(&db, parlamp::DEFAULT_ALPHA);
    println!("scenario {name}: {} | serial t1 = {t1:.3}s", res.summary());

    let coord = Coordinator::new(parlamp::DEFAULT_ALPHA)
        .with_calibration(cal)
        .with_screen(ScreenMode::Native);
    let mut t = Table::new(&[
        "P", "time(s)", "speedup", "eff", "ethernet(s)", "pre(s)", "main(s)", "probe(s)", "idle(s)",
    ]);
    for p in [1usize, 12, 24, 48, 96, 192, 300, 600, 1200] {
        let run = coord.run(&db, &Backend::sim(p)).expect("coordinated run");
        let time = run.t_parallel_s();
        let eth_backend = Backend::Sim { p, net: NetModel::ethernet(), seed: 2015 };
        let eth = coord.run(&db, &eth_backend).expect("ethernet run");
        let b = breakdown::sum(&run.phase1.breakdowns);
        let [pre, main, probe, idle] = b.as_secs();
        t.row(vec![
            p.to_string(),
            format!("{time:.4}"),
            format!("{:.1}x", t1 / time),
            format!("{:.0}%", 100.0 * t1 / time / p as f64),
            format!("{:.4}", eth.t_parallel_s()),
            format!("{pre:.3}"),
            format!("{main:.3}"),
            format!("{probe:.3}"),
            format!("{idle:.3}"),
        ]);
    }
    println!("{}", t.render());
    println!(
        "reading guide: near-linear speedup for large problems; the ethernet\n\
         column shows the paper's §5.2 claim that only the probe fraction\n\
         grows on a slow network."
    );
}
