//! Transaction databases in the paper's vertical bitmap layout.
//!
//! An item's column is its *occurrence bitmap* over transactions; support
//! counting is bitwise AND + popcount (paper §4.6). [`Database`] owns the
//! per-item bitmaps plus the positive-class mask used by the significance
//! statistics. The miner's hot path does not scan these full-width
//! columns per candidate, though: each expansion first projects the
//! node's [`ConditionalDb`] (item pruning, weighted row merging, adaptive
//! dense/sparse encoding — DESIGN.md §8) and checks against that.

mod io;
mod reduced;

pub use io::{read_labels, read_transactions, write_labels, write_transactions};
pub use reduced::{ConditionalDb, ProjectScratch};

use crate::bits::BitVec;
use crate::stats::Marginals;

/// Identifier of an item (column index after any preprocessing).
pub type Item = u32;

/// A binary transaction database with class labels, stored vertically.
///
/// # Examples
///
/// Supports, occurrences, and class statistics all come from the vertical
/// bitmap layout:
///
/// ```
/// use parlamp::db::Database;
///
/// // Three transactions over four items; the first two are positives.
/// let db = Database::from_transactions(
///     4,
///     &[vec![0, 1], vec![0, 1, 2], vec![1, 3]],
///     &[true, true, false],
/// );
/// assert_eq!((db.n_items(), db.n_trans()), (4, 3));
/// assert_eq!(db.support(&[0, 1]), 2);
/// assert_eq!(db.pos_support(&db.occurrence(&[0, 1])), 2);
/// assert!((db.density() - 7.0 / 12.0).abs() < 1e-12);
/// ```
///
/// The miner never scans these full-width columns per candidate: each
/// expansion projects the node's conditional database first (see
/// [`ConditionalDb`] and DESIGN.md §8).
#[derive(Clone, Debug)]
pub struct Database {
    n_trans: usize,
    /// `cols[i]` = occurrence bitmap of item `i` over transactions.
    cols: Vec<BitVec>,
    /// Bit `t` set iff transaction `t` is labelled positive.
    pos_mask: BitVec,
}

impl Database {
    /// Build from horizontal transactions (`trans[t]` = sorted-or-not item
    /// list of transaction `t`) and a positive-class indicator per
    /// transaction. `n_items` fixes the column count (items ≥ `n_items` are
    /// rejected).
    pub fn from_transactions(n_items: usize, trans: &[Vec<Item>], positive: &[bool]) -> Self {
        assert_eq!(trans.len(), positive.len(), "labels must match transactions");
        let n_trans = trans.len();
        let mut cols = vec![BitVec::zeros(n_trans); n_items];
        for (t, items) in trans.iter().enumerate() {
            for &i in items {
                assert!((i as usize) < n_items, "item {i} out of range {n_items}");
                cols[i as usize].set(t, true);
            }
        }
        let pos = positive.iter().enumerate().filter(|(_, p)| **p).map(|(t, _)| t);
        let pos_mask = BitVec::from_indices(n_trans, pos);
        Database { n_trans, cols, pos_mask }
    }

    /// Number of transactions `N`.
    pub fn n_trans(&self) -> usize {
        self.n_trans
    }

    /// Number of items (columns).
    pub fn n_items(&self) -> usize {
        self.cols.len()
    }

    /// Occurrence bitmap of item `i`.
    #[inline]
    pub fn col(&self, i: Item) -> &BitVec {
        &self.cols[i as usize]
    }

    /// Positive-class mask.
    pub fn pos_mask(&self) -> &BitVec {
        &self.pos_mask
    }

    /// Support of a single item.
    #[inline]
    pub fn item_support(&self, i: Item) -> u32 {
        self.cols[i as usize].count()
    }

    /// Occurrence bitmap of an itemset (AND over member columns); the
    /// all-ones vector for the empty set.
    pub fn occurrence(&self, items: &[Item]) -> BitVec {
        let mut occ = BitVec::ones(self.n_trans);
        for &i in items {
            occ = occ.and(self.col(i));
        }
        occ
    }

    /// Support of an itemset.
    pub fn support(&self, items: &[Item]) -> u32 {
        self.occurrence(items).count()
    }

    /// Positive-class support `n(I)` for an occurrence bitmap.
    #[inline]
    pub fn pos_support(&self, occ: &BitVec) -> u32 {
        occ.and_count(&self.pos_mask)
    }

    /// Statistical marginals `(N, N_pos)`.
    pub fn marginals(&self) -> Marginals {
        Marginals::new(self.n_trans as u32, self.pos_mask.count())
    }

    /// Fraction of set bits in the item-transaction matrix (the paper's
    /// "density" column in Table 1).
    pub fn density(&self) -> f64 {
        if self.n_items() == 0 || self.n_trans == 0 {
            return 0.0;
        }
        let ones: u64 = self.cols.iter().map(|c| c.count() as u64).sum();
        ones as f64 / (self.n_items() as f64 * self.n_trans as f64)
    }

    /// Drop items whose support is outside `[min_sup, max_sup]`, returning
    /// the remapped database and the mapping `new item -> old item`.
    ///
    /// This is the MAF-style frequency filter applied when preparing the
    /// GWAS inputs (paper §5.1): overly frequent or ultra-rare variants are
    /// excluded before mining.
    pub fn filter_items(&self, min_sup: u32, max_sup: u32) -> (Database, Vec<Item>) {
        let mut keep = Vec::new();
        for i in 0..self.n_items() as Item {
            let s = self.item_support(i);
            if s >= min_sup && s <= max_sup {
                keep.push(i);
            }
        }
        let cols = keep.iter().map(|&i| self.cols[i as usize].clone()).collect();
        (
            Database { n_trans: self.n_trans, cols, pos_mask: self.pos_mask.clone() },
            keep,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// 5 transactions, 4 items; transactions 0,1 positive.
    fn tiny() -> Database {
        let trans = vec![
            vec![0, 1, 2],
            vec![0, 1],
            vec![1, 2, 3],
            vec![0, 3],
            vec![1],
        ];
        let labels = vec![true, true, false, false, false];
        Database::from_transactions(4, &trans, &labels)
    }

    #[test]
    fn shape_and_supports() {
        let db = tiny();
        assert_eq!(db.n_trans(), 5);
        assert_eq!(db.n_items(), 4);
        assert_eq!(db.item_support(0), 3);
        assert_eq!(db.item_support(1), 4);
        assert_eq!(db.item_support(3), 2);
        assert_eq!(db.support(&[0, 1]), 2);
        assert_eq!(db.support(&[]), 5); // empty set occurs everywhere
        assert_eq!(db.support(&[0, 1, 2, 3]), 0);
    }

    #[test]
    fn positive_support_and_marginals() {
        let db = tiny();
        let m = db.marginals();
        assert_eq!((m.n, m.n_pos), (5, 2));
        let occ = db.occurrence(&[0, 1]);
        assert_eq!(db.pos_support(&occ), 2); // both transactions 0,1
        let occ3 = db.occurrence(&[3]);
        assert_eq!(db.pos_support(&occ3), 0);
    }

    #[test]
    fn density_counts_all_ones() {
        let db = tiny();
        // 3+4+2+2 = 11 ones over 4*5 cells
        assert!((db.density() - 11.0 / 20.0).abs() < 1e-12);
    }

    #[test]
    fn filter_items_remaps() {
        let db = tiny();
        let (f, map) = db.filter_items(3, 3);
        assert_eq!(map, vec![0]); // only item 0 has support exactly 3
        assert_eq!(f.n_items(), 1);
        assert_eq!(f.item_support(0), 3);
        assert_eq!(f.n_trans(), 5);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn rejects_out_of_range_items() {
        Database::from_transactions(2, &[vec![5]], &[true]);
    }
}
