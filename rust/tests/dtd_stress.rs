//! DTD stress: Mattern's time algorithm must never declare termination
//! while work or messages exist (safety) and must always fire once the
//! system is quiescent (liveness), under adversarial schedules.

use parlamp::dtd::{DtdNode, SpanningTree, WaveOutcome};
use parlamp::fabric::Msg;
use parlamp::util::propcheck::forall;
use parlamp::util::rng::Rng;

/// A toy distributed system: processes randomly exchange basic messages
/// for a while, then stop. The DTD runs waves concurrently; we check that
/// no wave reports a clean (count==0, valid, idle) completion while basic
/// messages are in flight, and that after quiescence a wave fires.
struct Sys {
    nodes: Vec<DtdNode>,
    /// In-flight basic messages: (dst, stamp).
    basic_in_flight: Vec<(usize, u64)>,
    /// In-flight control messages: (dst, msg).
    ctrl_in_flight: Vec<(usize, Msg)>,
    /// Whether each process still "works" (will send more basics).
    active: Vec<bool>,
}

impl Sys {
    fn new(p: usize) -> Sys {
        Sys {
            nodes: (0..p).map(|r| DtdNode::new(SpanningTree::ternary(r, p))).collect(),
            basic_in_flight: Vec::new(),
            ctrl_in_flight: Vec::new(),
            active: vec![true; p],
        }
    }

    fn quiescent(&self) -> bool {
        self.basic_in_flight.is_empty() && self.active.iter().all(|a| !a)
    }

    fn idle_vote(&self, r: usize) -> bool {
        !self.active[r]
    }

    fn deliver_ctrl(&mut self, idx: usize) -> Option<(bool, WaveOutcome)> {
        let (dst, msg) = self.ctrl_in_flight.swap_remove(idx);
        let mut out = Vec::new();
        let oc = match msg {
            Msg::WaveDown { t, lambda } => {
                let idle = self.idle_vote(dst);
                self.nodes[dst].on_wave_down(t, lambda, idle, vec![], &mut out);
                WaveOutcome::Pending
            }
            Msg::WaveUp { t, count, invalid, all_idle, hist } => {
                self.nodes[dst].on_wave_up(t, count, invalid, all_idle, hist, &mut out)
            }
            _ => unreachable!(),
        };
        for (d, m) in out {
            self.ctrl_in_flight.push((d, m));
        }
        Some((dst == 0, oc))
    }
}

#[test]
fn never_false_terminates_and_eventually_fires() {
    forall("DTD safety+liveness", 60, |rng: &mut Rng| {
        let p = 2 + rng.index(30);
        let mut sys = Sys::new(p);
        let mut wave_running = false;
        let mut clean_completions = 0u32;
        let steps = 400 + rng.index(400);
        let mut step = 0usize;
        loop {
            step += 1;
            if step > steps + 20_000 {
                return Err(format!("liveness violated: no clean wave after {step} steps"));
            }
            // Adversarial scheduler: pick an action at random.
            let action = rng.below(5);
            match action {
                // a process sends a basic message (while still active)
                0 if step < steps => {
                    let src = rng.index(p);
                    if sys.active[src] {
                        let stamp = sys.nodes[src].on_basic_sent();
                        let dst = rng.index(p);
                        sys.basic_in_flight.push((dst, stamp));
                    }
                }
                // a basic message is delivered
                1 if !sys.basic_in_flight.is_empty() => {
                    let i = rng.index(sys.basic_in_flight.len());
                    let (dst, stamp) = sys.basic_in_flight.swap_remove(i);
                    sys.nodes[dst].on_basic_recv(stamp);
                }
                // a process retires
                2 if step >= steps / 2 => {
                    let r = rng.index(p);
                    sys.active[r] = false;
                }
                // root initiates a wave
                3 if !wave_running => {
                    let idle = sys.idle_vote(0);
                    let mut out = Vec::new();
                    let oc = sys.nodes[0].initiate_wave(1, idle, vec![], &mut out);
                    for (d, m) in out {
                        sys.ctrl_in_flight.push((d, m));
                    }
                    wave_running = true;
                    if let WaveOutcome::Complete { count, invalid, all_idle, .. } = oc {
                        wave_running = false;
                        if count == 0 && !invalid && all_idle {
                            if !sys.quiescent() {
                                return Err("false termination (p=1 path)".into());
                            }
                            clean_completions += 1;
                        }
                    }
                }
                // a control message is delivered
                _ if !sys.ctrl_in_flight.is_empty() => {
                    let i = rng.index(sys.ctrl_in_flight.len());
                    if let Some((at_root, oc)) = sys.deliver_ctrl(i) {
                        if at_root {
                            if let WaveOutcome::Complete { count, invalid, all_idle, .. } = oc {
                                wave_running = false;
                                if count == 0 && !invalid && all_idle {
                                    // SAFETY: must be genuinely quiescent.
                                    if !sys.quiescent() {
                                        return Err(format!(
                                            "false termination at step {step}: {} in flight, active={:?}",
                                            sys.basic_in_flight.len(),
                                            sys.active
                                        ));
                                    }
                                    clean_completions += 1;
                                }
                            }
                        }
                    }
                }
                _ => {
                    // force progress when everything is drained
                    if step > steps {
                        for a in sys.active.iter_mut() {
                            *a = false;
                        }
                    }
                }
            }
            // LIVENESS: quiescent + a clean completion → done.
            if clean_completions > 0 {
                return Ok(());
            }
        }
    });
}

#[test]
fn clock_advances_once_per_wave() {
    let mut sys = Sys::new(7);
    for want_t in 1..=5u64 {
        let mut out = Vec::new();
        let _ = sys.nodes[0].initiate_wave(1, true, vec![], &mut out);
        for (d, m) in out {
            sys.ctrl_in_flight.push((d, m));
        }
        // drain to completion
        let mut done = false;
        while !sys.ctrl_in_flight.is_empty() {
            let i = sys.ctrl_in_flight.len() - 1;
            if let Some((at_root, oc)) = sys.deliver_ctrl(i) {
                if at_root && matches!(oc, WaveOutcome::Complete { .. }) {
                    done = true;
                }
            }
        }
        assert!(done);
        for n in &sys.nodes {
            assert_eq!(n.clock(), want_t);
        }
    }
}
